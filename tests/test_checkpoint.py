"""Checkpoint manager: roundtrip, rotation, atomicity, fault-tolerant resume
determinism, mesh-independence (restore with different sharding), and
cross-MESH-SHAPE restore of SUMO's edge-padded bucket stacks — a checkpoint
written on (data=8, model=1) restores onto (data=2, model=4) and vice versa
(the bucket key records the true long dim, so Q stacks re-pad/slice against
the template with no mesh metadata stored)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import SumoConfig, padded_long, sumo
from repro.train import CheckpointManager, FaultInjector, TrainConfig, train

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _state(key):
    return {
        "params": {"w": jax.random.normal(key, (8, 4)),
                   "blocks": [jnp.ones((2, 3)), jnp.zeros((5,))]},
        "step_things": {"count": jnp.asarray(7, jnp.int32), "none": None},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = _state(jax.random.PRNGKey(0))
    mgr.save(12, state, extra={"foo": "bar"})
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 12 and manifest["foo"] == "bar"
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_no_partial_checkpoints_visible(tmp_path):
    """tmp dirs never count as checkpoints (atomic rename discipline)."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    os.makedirs(os.path.join(str(tmp_path), "tmp.99"))
    assert mgr.latest_step() is None
    mgr.save(5, _state(jax.random.PRNGKey(2)))
    assert mgr.latest_step() == 5


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((8, 8))})


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _state(jax.random.PRNGKey(3)), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_fault_tolerant_resume_is_deterministic(tmp_path):
    """Training with a mid-run preemption reproduces the no-fault run exactly
    (checkpoint + deterministic data replay)."""
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")

    def run(fault, d):
        tcfg = TrainConfig(optimizer="sumo", learning_rate=1e-2, rank=4,
                           update_freq=5, total_steps=14, ckpt_dir=d,
                           ckpt_every=7, ckpt_async=False, log_every=1000)
        inj = FaultInjector(preempt_at=[9]) if fault else None
        return train(arch, shape, tcfg, fault_injector=inj, log_fn=lambda s: None)

    r_clean = run(False, str(tmp_path / "a"))
    r_fault = run(True, str(tmp_path / "b"))
    assert r_fault.restarts == 1
    clean = dict(r_clean.losses)
    fault = dict(r_fault.losses)
    for step in range(10, 14):   # post-recovery steps must match bit-for-bit
        assert abs(clean[step] - fault[step]) < 1e-6, step


# ---------------------------------------------------------------------------
# cross-mesh-shape restore: SUMO's edge-padded bucket Q stacks (ISSUE-5).
# Kept BELOW the fault-tolerance test: these warm the process with heavy
# compiles, which skews the StragglerMonitor's step-time medians inside
# that test when they run first (observed as spurious restarts).
# ---------------------------------------------------------------------------

def _ragged_params(key):
    """Two (102, 16) leaves -> one '102x16' bucket whose long dim is ragged
    on a model=4 axis (padded_long(102, 4) = 104)."""
    return {"a": jax.random.normal(key, (102, 16)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (102, 16))}


def _pad_state_q(state, multiple):
    """Manually edge-pad every bucket Q stack (what sumo(..., mesh=) stores
    on a model=`multiple` mesh) without needing the devices for a real mesh."""
    Q = {k: jnp.concatenate(
            [v, jnp.zeros((v.shape[0],
                           padded_long(v.shape[1], multiple) - v.shape[1],
                           v.shape[2]), v.dtype)], axis=1)
         for k, v in state.Q.items()}
    return state._replace(Q=Q)


def test_cross_mesh_restore_padded_to_true(tmp_path):
    """A checkpoint whose bucket Q stacks carry a (2,4)-mesh's pad rows
    restores into an unpadded (8,1)/no-mesh template: pad rows sliced off,
    everything else bit-identical, and the save recorded its padding in the
    manifest."""
    params = _ragged_params(jax.random.PRNGKey(0))
    cfg = SumoConfig(rank=4, update_freq=3)
    tx = sumo(0.01, cfg)
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    state = tx.init(params)
    for _ in range(2):            # real (non-zero) state, past the refresh
        _, state = tx.update(grads, state, params)
    padded = _pad_state_q(state, 4)
    assert padded.Q["102x16"].shape == (2, 104, 4)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"opt_state": padded}, extra={})
    assert mgr.read_manifest(2)["sumo_long_pad"] == {
        "opt_state|Q|102x16": {"true": 102, "padded": 104}}
    restored, _ = mgr.restore({"opt_state": tx.init(params)})
    for a, b in zip(jax.tree_util.tree_leaves(restored["opt_state"]),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cross_mesh_restore_true_to_padded(tmp_path):
    """The reverse direction: an unpadded ((8,1)-style) checkpoint restores
    into a padded-template state — true rows bit-identical, appended pad
    rows exactly zero."""
    params = _ragged_params(jax.random.PRNGKey(1))
    cfg = SumoConfig(rank=4, update_freq=3)
    tx = sumo(0.01, cfg)
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    state = tx.init(params)
    for _ in range(2):
        _, state = tx.update(grads, state, params)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"opt_state": state}, extra={})
    assert "sumo_long_pad" not in mgr.read_manifest(2)   # nothing padded
    template = {"opt_state": _pad_state_q(tx.init(params), 4)}
    restored, _ = mgr.restore(template)
    Q = np.asarray(restored["opt_state"].Q["102x16"])
    assert Q.shape == (2, 104, 4)
    np.testing.assert_array_equal(Q[:, :102], np.asarray(state.Q["102x16"]))
    assert float(np.abs(Q[:, 102:]).max()) == 0.0
    for f in ("M", "prev_norm"):
        np.testing.assert_array_equal(
            np.asarray(getattr(restored["opt_state"], f)["102x16"]),
            np.asarray(getattr(state, f)["102x16"]))


def test_cross_mesh_restore_through_layout_migration(tmp_path):
    """Layout migration and long-pad migration compose: a per-LEAF-layout
    checkpoint restores into a padded bucket-resident template (stack, then
    re-pad) and a padded bucket checkpoint restores into a per-leaf template
    (slice, then unstack)."""
    params = _ragged_params(jax.random.PRNGKey(2))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg_leaf = SumoConfig(rank=4, update_freq=3, state_layout="leaf")
    cfg_bkt = SumoConfig(rank=4, update_freq=3, state_layout="bucket")
    tx_leaf, tx_bkt = sumo(0.01, cfg_leaf), sumo(0.01, cfg_bkt)
    s_leaf = tx_leaf.init(params)
    for _ in range(2):
        _, s_leaf = tx_leaf.update(grads, s_leaf, params)
    s_bkt = tx_bkt.init(params)
    for _ in range(2):
        _, s_bkt = tx_bkt.update(grads, s_bkt, params)

    # leaf ckpt -> padded bucket template
    mgr = CheckpointManager(str(tmp_path / "leaf2pad"))
    mgr.save(2, {"opt_state": s_leaf})
    restored, _ = mgr.restore({"opt_state": _pad_state_q(tx_bkt.init(params), 4)})
    Q = np.asarray(restored["opt_state"].Q["102x16"])
    assert Q.shape == (2, 104, 4)
    np.testing.assert_array_equal(Q[:, :102],
                                  np.asarray(s_bkt.Q["102x16"]))
    assert float(np.abs(Q[:, 102:]).max()) == 0.0

    # padded bucket ckpt -> leaf template
    mgr2 = CheckpointManager(str(tmp_path / "pad2leaf"))
    mgr2.save(2, {"opt_state": _pad_state_q(s_bkt, 4)})
    restored2, _ = mgr2.restore({"opt_state": tx_leaf.init(params)})
    for a, b in zip(jax.tree_util.tree_leaves(restored2["opt_state"]),
                    jax.tree_util.tree_leaves(s_leaf)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_convert_sumo_state_repads_across_model_axes():
    """In-process cross-mesh migration: convert_sumo_state(long_pad_to=)
    normalizes a bucket Q stack padded for one model axis to another —
    including DOWN (model=8's 56 rows -> model=4's 52, -> model=1's true
    50), slicing only zero pad rows."""
    from repro.core import convert_sumo_state

    params = {"a": jax.random.normal(jax.random.PRNGKey(0), (50, 8)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (50, 8))}
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=4, update_freq=3)
    tx = sumo(0.01, cfg)
    s = tx.init(params)
    for _ in range(2):
        _, s = tx.update(grads, s, params)
    s8 = _pad_state_q(s, 8)                       # as a model=8 mesh stores it
    assert s8.Q["50x8"].shape == (2, 56, 4)
    s4 = convert_sumo_state(s8, params, cfg, "bucket", long_pad_to=4)
    assert s4.Q["50x8"].shape == (2, 52, 4)
    np.testing.assert_array_equal(np.asarray(s4.Q["50x8"][:, :50]),
                                  np.asarray(s.Q["50x8"]))
    assert float(jnp.abs(s4.Q["50x8"][:, 50:]).max()) == 0.0
    s1 = convert_sumo_state(s8, params, cfg, "bucket", long_pad_to=1)
    np.testing.assert_array_equal(np.asarray(s1.Q["50x8"]),
                                  np.asarray(s.Q["50x8"]))
    # default (no long_pad_to): bucket -> bucket stays the identity
    assert convert_sumo_state(s8, params, cfg, "bucket") is s8


def test_truncated_bucket_stack_restore_fails_loudly(tmp_path):
    """A bucket Q stack with FEWER rows than its key's true long dim is a
    truncated/corrupt checkpoint — restore must raise, not zero-fill the
    missing basis rows."""
    params = _ragged_params(jax.random.PRNGKey(5))
    cfg = SumoConfig(rank=4, update_freq=3)
    tx = sumo(0.01, cfg)
    state = tx.init(params)
    truncated = state._replace(
        Q={k: v[:, :90] for k, v in state.Q.items()})   # 90 < true 102
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"opt_state": truncated})
    with pytest.raises(ValueError, match="truncated or corrupt"):
        mgr.restore({"opt_state": tx.init(params)})


@needs_8_devices
def test_cross_mesh_checkpoint_round_trip_8dev(tmp_path):
    """The acceptance pin, end to end on real meshes: a checkpoint written
    by the (data=8, model=1) engine restores onto (data=2, model=4) with
    BIT-identical post-restore step deltas (vs the same state padded
    in-process — checkpoint I/O adds zero drift), and the round trip back
    onto (8,1) reproduces the original state and its next delta bit-exactly."""
    from repro.core import convert_sumo_state

    mesh81 = jax.make_mesh((8, 1), ("data", "model"))
    mesh24 = jax.make_mesh((2, 4), ("data", "model"))
    params = _ragged_params(jax.random.PRNGKey(3))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=4, update_freq=3, weight_decay=0.05)
    tx81 = sumo(0.01, cfg, mesh=mesh81)
    tx24 = sumo(0.01, cfg, mesh=mesh24)

    s81 = tx81.init(params)
    for _ in range(4):                      # past the step-3 refresh
        _, s81 = tx81.update(grads, s81, params)

    # In-process references are DEVICE_GET to host before re-entering an
    # engine on the other mesh: arrays still committed to mesh A fed into an
    # eager shard_map over mesh B mis-slice silently (a jax footgun the
    # checkpoint path never hits — restore hands back host arrays).
    host = lambda tree: jax.tree_util.tree_map(
        lambda x: np.asarray(x), tree, is_leaf=lambda x: x is None)

    # (8,1) -> (2,4): restored state == in-process padded state, bit for bit
    mgr = CheckpointManager(str(tmp_path / "a"))
    mgr.save(4, {"opt_state": s81})
    r24, _ = mgr.restore({"opt_state": tx24.init(params)})
    s24_ref = host(convert_sumo_state(s81, params, cfg, "bucket",
                                      long_pad_to=4))
    for a, b in zip(jax.tree_util.tree_leaves(r24["opt_state"]),
                    jax.tree_util.tree_leaves(s24_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    u_ckpt, s24n = tx24.update(grads, r24["opt_state"], params)
    u_ref, _ = tx24.update(grads, s24_ref, params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(u_ckpt[k]),
                                      np.asarray(u_ref[k]),
                                      err_msg=f"post-restore delta {k}")
    # and the migrated state still agrees with the 1D continuation
    u81, _ = tx81.update(grads, s81, params)
    for k in params:
        np.testing.assert_allclose(np.asarray(u_ckpt[k]), np.asarray(u81[k]),
                                   atol=1e-5, err_msg=f"2D-vs-1D delta {k}")

    # (2,4) -> (8,1): the round trip restores the true-row state bit-exactly
    # (pad rows sliced; the bucket key carries the true long dim)
    mgr2 = CheckpointManager(str(tmp_path / "b"))
    mgr2.save(5, {"opt_state": s24n})
    r81, _ = mgr2.restore({"opt_state": tx81.init(params)})
    assert r81["opt_state"].Q["102x16"].shape == (2, 102, 4)
    s81_ref = host(s24n._replace(
        Q={k: v[:, :int(k.split("x")[0])] for k, v in s24n.Q.items()}))
    for a, b in zip(jax.tree_util.tree_leaves(r81["opt_state"]),
                    jax.tree_util.tree_leaves(s81_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    u_back, _ = tx81.update(grads, r81["opt_state"], params)
    u_noround, _ = tx81.update(grads, s81_ref, params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(u_back[k]),
                                      np.asarray(u_noround[k]),
                                      err_msg=f"round-trip delta {k}")


# ---------------------------------------------------------------------------
# DP-compression comp_state slot: pre-dp leniency + elastic worker axis
# ---------------------------------------------------------------------------

def _comp_template(n_workers):
    from repro.parallel import CompressionConfig, init_worker_state
    grads = {"w": jnp.zeros((128, 16)), "tiny": jnp.zeros((4, 4))}
    cfg = CompressionConfig(rank=8, min_dim=64)
    return {"params": grads,
            "comp_state": init_worker_state(grads, cfg, n_workers)}


def test_restore_pre_dp_checkpoint_cold_starts_comp_state(tmp_path):
    """A checkpoint written WITHOUT dp_compress restores into a dp template:
    the comp_state slot keeps the template's fresh EF state (zero residuals,
    step 0) instead of raising — EF is a correction term, not model state —
    while a genuinely missing PARAM leaf still fails loudly."""
    state = _comp_template(4)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"params": state["params"]})        # pre-dp payload
    restored, _ = mgr.restore(state)
    assert int(restored["comp_state"].step) == 0
    np.testing.assert_array_equal(
        np.asarray(restored["comp_state"].error["w"]),
        np.zeros((4, 128, 16), np.float32))
    with pytest.raises(KeyError):
        mgr.restore({"params": dict(state["params"], extra=jnp.zeros((2,)))})


def test_comp_state_worker_axis_migration_is_sum_preserving(tmp_path):
    """Elastic DP restore: EF residuals written on W=4 workers restore onto
    W'=2 with the residual SUM preserved (e'_i = sum_w e_w / W'), so the
    global correction the next steps apply is unchanged; a non-worker shape
    mismatch still raises."""
    state = _comp_template(4)
    err = jax.random.normal(jax.random.PRNGKey(5), (4, 128, 16))
    state["comp_state"] = state["comp_state"]._replace(
        error={"w": err, "tiny": None},
        step=jnp.asarray(9, jnp.int32))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(9, state)

    narrow = _comp_template(2)
    restored, _ = mgr.restore(narrow)
    got = np.asarray(restored["comp_state"].error["w"])
    assert got.shape == (2, 128, 16)
    total = np.asarray(err).sum(0)
    np.testing.assert_allclose(got.sum(0), total, atol=1e-4)
    np.testing.assert_allclose(got[0], total / 2, atol=1e-5)
    assert int(restored["comp_state"].step) == 9

    # same key, mismatched NON-worker dims -> loud failure, not migration
    bad = _comp_template(4)
    bad["comp_state"] = bad["comp_state"]._replace(
        error={"w": jnp.zeros((4, 64, 16)), "tiny": None})
    with pytest.raises(ValueError, match="worker dim"):
        mgr.restore(bad)
