"""DP gradient compression: exactness properties, error feedback convergence
(simulated multi-worker sync), wire-byte ratio, and the regression pins for
the module's fixed latent bugs (vacuous eligibility, double compression,
EF-off residual allocation, element-counted ratios)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.compression import (
    CompressionConfig,
    compress_grads,
    compress_leaf,
    compression_ratio,
    decompress_leaf,
    dp_wire_plan,
    eligible,
    finalize,
    init_state,
    init_worker_state,
)


def test_roundtrip_is_projection():
    """decompress(compress(G)) is the orthogonal projection of G onto the
    sketch subspace: idempotent and norm-non-increasing."""
    key = jax.random.PRNGKey(0)
    G = jax.random.normal(key, (512, 64))
    skey = jax.random.PRNGKey(7)
    d1 = decompress_leaf(compress_leaf(G, skey, 16), skey, G.shape)
    d2 = decompress_leaf(compress_leaf(d1, skey, 16), skey, G.shape)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)
    assert float(jnp.linalg.norm(d1)) <= float(jnp.linalg.norm(G)) + 1e-4


def test_low_rank_gradient_transmits_losslessly_in_expectation():
    """A gradient already inside a rank-<r subspace loses little energy
    under an oversampled sketch... exact when the sketch contains it."""
    key = jax.random.PRNGKey(1)
    U = jnp.linalg.qr(jax.random.normal(key, (256, 4)))[0]
    C = jax.random.normal(jax.random.fold_in(key, 2), (4, 32))
    G = U @ C
    skey = jax.random.PRNGKey(3)
    dec = decompress_leaf(compress_leaf(G, skey, 64), skey, G.shape)
    # random 64-dim sketch of a 256-dim space captures ~64/256 energy of a
    # fixed subspace; with EF the rest arrives over subsequent steps — here
    # we just check the projection is substantial and bounded
    ratio = float(jnp.linalg.norm(dec)) / float(jnp.linalg.norm(G))
    assert 0.3 < ratio <= 1.0


def test_error_feedback_sync_converges_to_exact_mean():
    """4 simulated workers with different gradients: compressed+EF sync
    accumulates to the exact mean over steps (EF guarantee)."""
    cfg = CompressionConfig(rank=32, min_dim=16, error_feedback=True)
    key = jax.random.PRNGKey(4)
    n_workers = 4
    G_true = jax.random.normal(key, (n_workers, 128, 32))
    grads_t = {"w": G_true[0]}
    states = [init_state(grads_t, cfg) for _ in range(n_workers)]

    exact_mean = jnp.mean(G_true, axis=0)
    acc = jnp.zeros((128, 32))
    T = 100
    for step in range(T):
        payloads, metas = [], []
        treedef = None
        for w in range(n_workers):
            p, m, treedef = compress_grads({"w": G_true[w]}, states[w], cfg)
            payloads.append(p)
            metas.append(m)
        mean_payload = jax.tree_util.tree_map(
            lambda *xs: sum(xs) / n_workers, *payloads
        )
        new_states = []
        decoded = None
        for w in range(n_workers):
            g, s = finalize(mean_payload, metas[w], treedef, states[w], cfg)
            decoded = g
            new_states.append(s)
        states = new_states
        acc = acc + decoded["w"]
    # the running average of decoded syncs approaches the exact mean (~1/T)
    err = float(jnp.linalg.norm(acc / T - exact_mean)) / float(
        jnp.linalg.norm(exact_mean)
    )
    assert err < 0.08, err


def test_compression_ratio():
    cfg = CompressionConfig(rank=32, min_dim=128)
    grads = {
        "big": jnp.zeros((4096, 1024)),
        "small": jnp.zeros((64, 64)),
        "vec": jnp.zeros((512,)),
    }
    r = compression_ratio(grads, cfg)
    # big: 32*1024 vs 4096*1024 -> 1/128 of its share
    assert r < 0.05


def test_uncompressed_leaves_pass_through():
    cfg = CompressionConfig(rank=8, min_dim=1024)
    grads = {"w": jnp.ones((64, 32))}      # below min_dim -> exact path
    state = init_state(grads, cfg)
    p, m, treedef = compress_grads(grads, state, cfg)
    g, _ = finalize(p, m, treedef, state, cfg)
    np.testing.assert_array_equal(np.asarray(g["w"]), np.ones((64, 32)))


# ---------------------------------------------------------------------------
# Regression pins for the fixed latent bugs
# ---------------------------------------------------------------------------

def test_eligibility_is_not_vacuous():
    """Bugfix 1: the old ``max(leaf.shape) >= 1`` was true for EVERYTHING.
    The shared predicate must actually discriminate: min_dim applies to the
    CANONICAL long dim (max of the trailing two), ndim < 2 is exact-path,
    and None is never eligible."""
    cfg = CompressionConfig(rank=8, min_dim=256)
    assert not eligible(jnp.zeros((16, 16)), cfg)
    assert not eligible(jnp.zeros((512,)), cfg)         # 1D, however long
    assert not eligible(None, cfg)
    assert eligible(jnp.zeros((300, 8)), cfg)
    assert eligible(jnp.zeros((8, 300)), cfg)           # transposed view
    assert eligible(jnp.zeros((4, 300, 8)), cfg)        # batch dims allowed
    assert not eligible(jnp.zeros((300, 4, 8)), cfg)    # long dim is a batch


def test_state_grads_divergence_fails_loudly():
    """Bugfix 1 (second half): eligibility used to live implicitly in
    ``init_state``'s error tree, so a grads/state divergence silently
    mis-decided leaves. Now any mismatch raises."""
    cfg = CompressionConfig(rank=8, min_dim=64)
    grads = {"a": jnp.ones((128, 16)), "b": jnp.ones((8, 8))}
    state = init_state(grads, cfg)
    # different tree structure
    with pytest.raises(ValueError, match="different template"):
        compress_grads({"a": jnp.ones((128, 16))}, state, cfg)
    # same structure, EF-slot disagreement (state built under a different cfg)
    with pytest.raises(ValueError, match="EF residual"):
        compress_grads(
            grads, state, CompressionConfig(rank=8, min_dim=64,
                                            error_feedback=False))
    # same structure, residual of the wrong shape
    bad = state._replace(error={"a": jnp.zeros((64, 16)), "b": None})
    with pytest.raises(ValueError, match="shape"):
        compress_grads(grads, bad, cfg)


def test_single_compression_per_leaf_per_step(monkeypatch):
    """Bugfix 2: ``finalize`` used to RE-compress each gradient to rebuild
    the EF residual (and ferried the full-size g32 through meta).
    ``compress_leaf`` must run exactly once per eligible leaf per step, and
    meta must not carry a full-size gradient copy."""
    import repro.parallel.compression as comp

    calls = []
    real = comp.compress_leaf
    monkeypatch.setattr(
        comp, "compress_leaf",
        lambda G, key, r, Q=None: calls.append(G.shape) or real(G, key, r, Q=Q))

    cfg = CompressionConfig(rank=8, min_dim=64)
    grads = {"a": jnp.ones((128, 16)), "b": jnp.ones((96, 8)),
             "tiny": jnp.ones((4, 4))}
    state = init_state(grads, cfg)
    p, m, treedef = comp.compress_grads(grads, state, cfg)
    comp.finalize(p, m, treedef, state, cfg)
    assert calls == [(128, 16), (96, 8)]     # once per eligible leaf, total
    # meta's only full-size array is the NEXT EF residual, not a g32 copy
    for entry in m:
        if entry is None:
            continue
        shape, _, err = entry
        assert err.shape == shape            # exactly one full-size buffer


def test_ef_off_stores_none_not_zeros():
    """Bugfix 3: error_feedback=False used to allocate full-size zero
    residuals (a dead full-model-size buffer donated through every step).
    Now the error slots are None — same TREE STRUCTURE, no storage — in
    both the single- and the worker-stacked init, and stay None through a
    step."""
    cfg = CompressionConfig(rank=8, min_dim=64, error_feedback=False)
    grads = {"a": jnp.ones((128, 16)), "tiny": jnp.ones((4, 4))}
    for st in (init_state(grads, cfg), init_worker_state(grads, cfg, 4)):
        assert all(e is None for e in jax.tree_util.tree_leaves(
            st.error, is_leaf=lambda x: x is None))
        # structure still mirrors grads (treedef-compatible), so shard_map
        # specs and donation line up leaf-for-leaf
        jax.tree_util.tree_structure(grads).flatten_up_to(st.error)
    state = init_state(grads, cfg)
    p, m, treedef = compress_grads(grads, state, cfg)
    _, new_state = finalize(p, m, treedef, state, cfg)
    assert all(e is None for e in jax.tree_util.tree_leaves(
        new_state.error, is_leaf=lambda x: x is None))


def test_compression_ratio_is_byte_accurate():
    """Bugfix 4 + the bf16 wire: the ratio counts BYTES at each buffer's
    wire dtype — compressed payloads ride ``cfg.payload_dtype`` (bf16 by
    default, 2 B), exact leaves their own dtype — and the plan separately
    records ``hlo_bytes``, where XLA's all-reduce promotion upcasts sub-f32
    float collectives to f32."""
    cfg = CompressionConfig(rank=16, min_dim=128)
    big16 = {"w": jnp.zeros((1024, 64), jnp.bfloat16)}
    # bf16 payload 16*64*2 B over bf16 full 1024*64*2 B
    assert compression_ratio(big16, cfg) == pytest.approx(16 / 1024)
    big32 = {"w": jnp.zeros((1024, 64), jnp.float32)}
    assert compression_ratio(big32, cfg) == pytest.approx(
        (16 * 64 * 2) / (1024 * 64 * 4))
    # an f32 payload restores the old accounting
    cfg32 = CompressionConfig(rank=16, min_dim=128, payload_dtype="float32")
    assert compression_ratio(big32, cfg32) == pytest.approx(16 / 1024)
    # the compiled-HLO view promotes the bf16 payload back to f32
    plan = dp_wire_plan(big32, cfg)
    assert plan[0].payload_bytes == 16 * 64 * 2
    assert plan[0].hlo_bytes == 16 * 64 * 4
    # exact leaves keep their own dtype on the wire
    plan = dp_wire_plan({"t": jnp.zeros((8, 8), jnp.bfloat16)}, cfg)
    assert plan[0].payload_bytes == 8 * 8 * 2
    assert plan[0].hlo_bytes == 8 * 8 * 4   # promoted like any sub-f32 float
    assert not plan[0].eligible
