"""DP gradient compression: exactness properties, error feedback convergence
(simulated multi-worker sync), wire-byte ratio."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compression import (
    CompressionConfig,
    compress_grads,
    compress_leaf,
    compression_ratio,
    decompress_leaf,
    finalize,
    init_state,
)


def test_roundtrip_is_projection():
    """decompress(compress(G)) is the orthogonal projection of G onto the
    sketch subspace: idempotent and norm-non-increasing."""
    key = jax.random.PRNGKey(0)
    G = jax.random.normal(key, (512, 64))
    skey = jax.random.PRNGKey(7)
    d1 = decompress_leaf(compress_leaf(G, skey, 16), skey, G.shape)
    d2 = decompress_leaf(compress_leaf(d1, skey, 16), skey, G.shape)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)
    assert float(jnp.linalg.norm(d1)) <= float(jnp.linalg.norm(G)) + 1e-4


def test_low_rank_gradient_transmits_losslessly_in_expectation():
    """A gradient already inside a rank-<r subspace loses little energy
    under an oversampled sketch... exact when the sketch contains it."""
    key = jax.random.PRNGKey(1)
    U = jnp.linalg.qr(jax.random.normal(key, (256, 4)))[0]
    C = jax.random.normal(jax.random.fold_in(key, 2), (4, 32))
    G = U @ C
    skey = jax.random.PRNGKey(3)
    dec = decompress_leaf(compress_leaf(G, skey, 64), skey, G.shape)
    # random 64-dim sketch of a 256-dim space captures ~64/256 energy of a
    # fixed subspace; with EF the rest arrives over subsequent steps — here
    # we just check the projection is substantial and bounded
    ratio = float(jnp.linalg.norm(dec)) / float(jnp.linalg.norm(G))
    assert 0.3 < ratio <= 1.0


def test_error_feedback_sync_converges_to_exact_mean():
    """4 simulated workers with different gradients: compressed+EF sync
    accumulates to the exact mean over steps (EF guarantee)."""
    cfg = CompressionConfig(rank=32, min_dim=16, error_feedback=True)
    key = jax.random.PRNGKey(4)
    n_workers = 4
    G_true = jax.random.normal(key, (n_workers, 128, 32))
    grads_t = {"w": G_true[0]}
    states = [init_state(grads_t, cfg) for _ in range(n_workers)]

    exact_mean = jnp.mean(G_true, axis=0)
    acc = jnp.zeros((128, 32))
    T = 100
    for step in range(T):
        payloads, metas = [], []
        treedef = None
        for w in range(n_workers):
            p, m, treedef = compress_grads({"w": G_true[w]}, states[w], cfg)
            payloads.append(p)
            metas.append(m)
        mean_payload = jax.tree_util.tree_map(
            lambda *xs: sum(xs) / n_workers, *payloads
        )
        new_states = []
        decoded = None
        for w in range(n_workers):
            g, s = finalize(mean_payload, metas[w], treedef, states[w], cfg)
            decoded = g
            new_states.append(s)
        states = new_states
        acc = acc + decoded["w"]
    # the running average of decoded syncs approaches the exact mean (~1/T)
    err = float(jnp.linalg.norm(acc / T - exact_mean)) / float(
        jnp.linalg.norm(exact_mean)
    )
    assert err < 0.08, err


def test_compression_ratio():
    cfg = CompressionConfig(rank=32, min_dim=128)
    grads = {
        "big": jnp.zeros((4096, 1024)),
        "small": jnp.zeros((64, 64)),
        "vec": jnp.zeros((512,)),
    }
    r = compression_ratio(grads, cfg)
    # big: 32*1024 vs 4096*1024 -> 1/128 of its share
    assert r < 0.05


def test_uncompressed_leaves_pass_through():
    cfg = CompressionConfig(rank=8, min_dim=1024)
    grads = {"w": jnp.ones((64, 32))}      # below min_dim -> exact path
    state = init_state(grads, cfg)
    p, m, treedef = compress_grads(grads, state, cfg)
    g, _ = finalize(p, m, treedef, state, cfg)
    np.testing.assert_array_equal(np.asarray(g["w"]), np.ones((64, 32)))
