"""Per-arch smoke tests (reduced configs) + decode↔forward parity + flash vjp."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs import SHAPES, cell_supported
from repro.models import (
    decode_step,
    forward_logits,
    init_decode_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.flash import flash_attention
from repro.models.layers import attention_ref


def _smoke_batch(cfg, key, B=2, L=24):
    if cfg.family == "audio":
        return {
            "frontend_embeds": jax.random.normal(key, (B, L, cfg.d_model)),
            "labels": jax.random.randint(key, (B, L), 0, cfg.vocab),
        }
    batch = {
        "tokens": jax.random.randint(key, (B, L), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, L), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch_id):
    """One forward + one grad step on the reduced config: shapes + no NaNs."""
    cfg = get_smoke_config(arch_id)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _smoke_batch(cfg, key)

    l, grads = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, cfg, batch)))(params)
    assert np.isfinite(float(l))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0

    if cfg.family not in ("audio",):
        logits = forward_logits(params, cfg, batch)
        B = batch["tokens"].shape[0] if "tokens" in batch else 2
        assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
        assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch_id", ["qwen3-4b", "mixtral-8x22b", "zamba2-7b",
                                     "xlstm-1.3b", "stablelm-1.6b"])
def test_decode_matches_forward(arch_id):
    """Sequential decode == teacher-forced forward (the serving invariant)."""
    import dataclasses
    cfg = get_smoke_config(arch_id)
    if cfg.moe is not None:
        from repro.configs.base import MoEConfig
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(cfg.moe.num_experts, cfg.moe.top_k,
                               capacity_factor=8.0))  # no token drops
    key = jax.random.PRNGKey(42)
    params = init_params(cfg, key)
    B, L = 2, 12
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab)
    full = forward_logits(params, cfg, {"tokens": toks}, attn_impl="ref")
    cache = init_decode_cache(cfg, B, L + 4)
    outs = []
    for t in range(L):
        lg, cache = decode_step(params, cfg, toks[:, t : t + 1], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-4)


def test_prefill_then_decode_matches_forward():
    cfg = get_smoke_config("qwen3-4b")
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    B, L = 2, 16
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab)
    full = forward_logits(params, cfg, {"tokens": toks}, attn_impl="ref")
    lg, cache = prefill(params, cfg, {"tokens": toks[:, : L - 1]},
                        cache_len=L + 4, attn_impl="ref")
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, L - 2]),
                               atol=2e-4)
    lg2, _ = decode_step(params, cfg, toks[:, L - 1 :], cache)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, L - 1]),
                               atol=2e-4)


@pytest.mark.parametrize("causal,win", [(True, None), (True, 48), (False, None)])
def test_flash_attention_grads(causal, win):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    B, L, H, KV, hd = 2, 150, 4, 2, 32
    q = jax.random.normal(ks[0], (B, L, H, hd))
    k = jax.random.normal(ks[1], (B, L, KV, hd))
    v = jax.random.normal(ks[2], (B, L, KV, hd))
    do = jax.random.normal(ks[3], (B, L, H, hd))
    f1 = lambda q, k, v: jnp.sum(flash_attention(q, k, v, causal, win, 64, 64) * do)
    f2 = lambda q, k, v: jnp.sum(
        attention_ref(q, k, v, causal=causal, sliding_window=win) * do
    )
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_cell_support_matrix():
    """The assignment's skip rules: encoder-only decode + quadratic 500k."""
    rows = {}
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        rows[arch_id] = [cell_supported(cfg, s)[0] for s in SHAPES]
    assert rows["hubert-xlarge"] == [True, True, False, False]
    assert rows["zamba2-7b"] == [True, True, True, True]
    assert rows["xlstm-1.3b"] == [True, True, True, True]
    assert rows["mixtral-8x22b"] == [True, True, True, True]     # SWA
    assert rows["qwen3-4b"] == [True, True, True, False]         # quadratic
    assert rows["granite-moe-3b-a800m"] == [True, True, True, False]  # no SWA
    n_supported = sum(sum(r) for r in rows.values())
    assert n_supported == 32   # 40 cells − 8 architectural skips


def test_param_counts_sane():
    """Full configs should land near their nameplate sizes."""
    approx = {
        "qwen3-4b": (3.0e9, 5.5e9),
        "smollm-360m": (3.0e8, 4.5e8),
        "deepseek-coder-33b": (2.7e10, 3.9e10),
        "mixtral-8x22b": (1.2e11, 1.6e11),
    }
    for arch_id, (lo, hi) in approx.items():
        n = get_config(arch_id).param_count()
        assert lo < n < hi, (arch_id, n)
