"""Sharding rules + a real multi-device pjit compile in a subprocess (the
main test process must keep 1 device for everything else)."""
import subprocess
import sys

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.parallel import param_spec


def _mesh(shape=(16, 16), axes=("data", "model")):
    # AbstractMesh takes a shape_tuple of (axis_name, size) pairs.
    return AbstractMesh(tuple(zip(axes, shape)))


def test_megatron_rules():
    mesh = _mesh()
    assert param_spec("blocks/attn/wq", (4096, 4096), mesh) == P(None, "model")
    assert param_spec("blocks/attn/wo", (4096, 4096), mesh) == P("model", None)
    assert param_spec("blocks/mlp/w_up", (4096, 14336), mesh) == P(None, "model")
    assert param_spec("blocks/mlp/w_down", (14336, 4096), mesh) == P("model", None)
    assert param_spec("embed_tokens", (32000, 4096), mesh) == P("model", None)
    assert param_spec("final_norm/norm_scale", (4096,), mesh) == P()


def test_non_divisible_falls_back():
    mesh = _mesh()
    # vocab 49155 is not divisible by 16 -> shard the other dim
    assert param_spec("embed_tokens", (49155, 1536), mesh) == P(None, "model")
    # nothing divisible -> replicated
    assert param_spec("blocks/x", (15, 9), mesh) == P()


def test_expert_stack_spec():
    mesh = _mesh()
    # 8 experts not divisible by 16 -> trailing dim over model
    assert param_spec("experts/w_gate", (8, 6144, 16384), mesh) == P(None, None, "model")
    # 32 experts divisible -> expert-parallel
    assert param_spec("experts/w_gate", (32, 1536, 512), mesh) == P("model", None, None)


def test_fsdp_adds_data_axis():
    mesh = _mesh()
    cfg = get_config("deepseek-coder-33b")
    assert cfg.fsdp
    spec = param_spec("blocks/mlp/w_up", (7168, 19200), mesh, cfg)
    assert spec == P("data", "model")


def test_bucket_state_specs():
    """Bucket-resident SUMO state: B over the bucket axis, Q's long dim over
    model for the pjit path — and replicated-on-model when the shard_map
    bucket update owns the state (its body needs the full long dim)."""
    from repro.parallel import bucket_state_spec

    mesh = _mesh((16, 16))
    assert bucket_state_spec("opt/matrix/Q/4096x1024", (32, 4096, 128), mesh) \
        == P("data", "model", None)
    assert bucket_state_spec("opt/matrix/M/4096x1024", (32, 128, 1024), mesh) \
        == P("data", None, None)
    assert bucket_state_spec("opt/matrix/prev_norm/4096x1024", (32,), mesh) \
        == P("data")
    # shard_map-compatible placement: long dim stays replicated
    assert bucket_state_spec("opt/matrix/Q/4096x1024", (32, 4096, 128), mesh,
                             long_over_model=False) == P("data", None, None)
    # indivisible B falls back to replicated on that dim
    assert bucket_state_spec("opt/matrix/Q/4096x1024", (3, 4096, 128), mesh) \
        == P(None, "model", None)
    # non-bucket paths are not claimed
    assert bucket_state_spec("opt/matrix/Q/blocks/wq", (4096, 128), mesh) is None
    assert bucket_state_spec("opt/fallback/mu/64x32", (2, 64, 32), mesh) is None
    # an edge-padded ragged bucket (true long 1000, stored 1008 on model=16):
    # the PADDED row count is what must divide, and does
    assert bucket_state_spec("opt/matrix/Q/1000x64", (32, 1008, 16), mesh) \
        == P("data", "model", None)
    # a true-shaped Q that does NOT divide (state not built for this mesh)
    # stays replicated on model so device_put remains correct
    assert bucket_state_spec("opt/matrix/Q/1000x64", (32, 1000, 16), mesh) \
        == P("data", None, None)


def test_host_mesh_clamp_warns_and_strict_raises():
    """make_host_mesh silently shrinking the model axis (e.g. model=4 on 6
    devices -> 2) hid real capacity changes: now it warns, and strict mode
    refuses to build a different mesh than requested."""
    import warnings
    from repro.launch.mesh import make_host_mesh

    n = len(jax.devices())
    bad = n + 1   # never divides the device count (and exceeds it)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh = make_host_mesh(model=bad)
    assert any("does not divide" in str(x.message) for x in w)
    assert mesh.shape["model"] <= n
    with pytest.raises(ValueError, match="does not divide"):
        make_host_mesh(model=bad, strict=True)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh = make_host_mesh(model=1)   # always divides: no warning
    assert not w and mesh.shape["model"] == 1


def test_production_mesh_validates_device_count():
    """make_production_mesh on too small a slice fails with a clear message
    naming the requested shape, not an opaque make_mesh error. (Extra
    devices are fine — make_mesh truncates; the dry-run relies on that.)"""
    from repro.launch.mesh import make_production_mesh

    if len(jax.devices()) >= 256:   # a real slice: should build
        make_production_mesh()
        return
    with pytest.raises(ValueError, match="needs 256 devices"):
        make_production_mesh()
    with pytest.raises(ValueError, match="needs 512 devices"):
        make_production_mesh(multi_pod=True)


@pytest.mark.slow
def test_multi_device_pjit_compiles():
    """Real 8-device (2 data × 4 model) lower+compile of a SUMO train step."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import SumoConfig, sumo_optimizer
from repro.models import init_params, input_specs
from repro.parallel import tree_param_specs, opt_state_specs, input_specs_sharding
from repro.train.steps import make_train_step
import dataclasses

cfg = get_smoke_config("qwen3-4b")
cfg = dataclasses.replace(cfg, d_model=64, n_layers=2, head_dim=16)
shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="train")
mesh = jax.make_mesh((2, 4), ("data", "model"))
params_s = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
tx = sumo_optimizer(1e-3, params_s, SumoConfig(rank=4, update_freq=10))
opt_s = jax.eval_shape(tx.init, params_s)
named = lambda specs: jax.tree_util.tree_map(
    lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, specs,
    is_leaf=lambda x: isinstance(x, P) or x is None)
p_sh = named(tree_param_specs(params_s, mesh, cfg))
o_sh = named(opt_state_specs(opt_s, mesh, cfg))
b_s = input_specs(cfg, shape)
b_sh = named(input_specs_sharding(b_s, mesh, shape.global_batch))
with mesh:
    step = make_train_step(cfg, tx)
    compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
        params_s, opt_s, b_s).compile()
mem = compiled.memory_analysis()
assert "all-reduce" in compiled.as_text() or "all-gather" in compiled.as_text()
print("OK", mem.temp_size_in_bytes)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**__import__("os").environ, "PYTHONPATH": "src"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
