"""Data pipeline determinism + trip-count-aware HLO cost analysis."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, make_batch
from repro.roofline.hlo_cost import analyze_hlo


def test_data_deterministic_per_step():
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    b1 = make_batch(17, shape, arch)
    b2 = make_batch(17, shape, arch)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = make_batch(18, shape, arch)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_data_labels_are_next_tokens():
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    b = make_batch(0, shape, arch, DataConfig(seed=3))
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    assert int(jnp.max(b["tokens"])) < arch.vocab


def test_data_has_learnable_structure():
    """repeat_prob>0 ⇒ adjacent-window copies appear well above chance."""
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("t", seq_len=512, global_batch=4, kind="train")
    b = make_batch(0, shape, arch, DataConfig(repeat_prob=0.5))
    t = np.asarray(b["tokens"])
    hits = 0
    for d in range(1, 9):
        hits += np.mean(t[:, d:] == t[:, :-d])
    assert hits > 0.3   # chance level would be ~8/vocab ≈ 0.03


def test_hlo_cost_trip_counts():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(s, s).compile()
    cost = analyze_hlo(compiled.as_text())
    expected = 2 * 64**3 * 10
    assert cost.unknown_trip_loops == 0
    assert 0.9 * expected < cost.flops < 1.3 * expected


def test_hlo_cost_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    s = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    compiled = jax.jit(f).lower(s, s).compile()
    cost = analyze_hlo(compiled.as_text())
    expected = 2 * 32**3 * 15
    assert 0.9 * expected < cost.flops < 1.5 * expected


def test_hlo_cost_counts_collectives_in_loops():
    import subprocess, sys, os
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo_cost import analyze_hlo
mesh = jax.make_mesh((4,), ("model",))
def g(x, w):
    def body(c, _):
        return c @ w, None
    c, _ = jax.lax.scan(body, x, None, length=5)
    return c
xs = jax.ShapeDtypeStruct((8, 64), jnp.float32)
ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
with mesh:
    comp = jax.jit(g, in_shardings=(NamedSharding(mesh, P()),
                                     NamedSharding(mesh, P("model", None)))).lower(xs, ws).compile()
c = analyze_hlo(comp.as_text())
assert c.collective_bytes > 0, "expected collectives in sharded matmul loop"
print("OK", int(c.collective_bytes))
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
