"""MoE dispatch correctness vs a dense naive reference + capacity semantics.

Property tests are gated on `hypothesis` being importable (the offline
container lacks it); the deterministic smoke replays below always run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = st = None

from repro.configs import get_smoke_config
from repro.configs.base import MoEConfig
from repro.models.moe import _capacity, apply_moe, init_moe


def _naive_moe(p, x, cfg):
    m = cfg.moe
    T = x.shape[0] * x.shape[1]
    xt = x.reshape(T, -1)
    probs = jax.nn.softmax(xt @ p["router"], -1)
    g, idx = jax.lax.top_k(probs, m.top_k)
    g = g / jnp.sum(g, -1, keepdims=True)
    W = p["experts"]
    outs = jnp.zeros_like(xt)
    for e in range(m.num_experts):
        h = jax.nn.silu(xt @ W["w_gate"][e]) * (xt @ W["w_up"][e])
        y = h @ W["w_down"][e]
        w_e = jnp.sum(jnp.where(idx == e, g, 0.0), -1)
        outs += y * w_e[:, None]
    return outs.reshape(x.shape)


def _cfg(E=4, k=2, cf=8.0):
    base = get_smoke_config("mixtral-8x22b")
    return dataclasses.replace(base, moe=MoEConfig(E, k, capacity_factor=cf))


def test_moe_matches_naive_no_drops():
    cfg = _cfg(cf=8.0)   # capacity high enough that nothing drops
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 12, cfg.d_model)) * 0.5
    out, aux = apply_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_naive_moe(p, x, cfg)),
                               atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity 1.0× an unbalanced router must drop; output stays finite
    and dropped tokens contribute zero (residual passthrough upstream)."""
    cfg = _cfg(cf=0.25)
    key = jax.random.PRNGKey(1)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    out, _ = apply_moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    # some token rows must be exactly zero (dropped from every expert)
    norms = jnp.linalg.norm(out.reshape(-1, cfg.d_model), axis=-1)
    assert float(jnp.min(norms)) == 0.0


def test_moe_grads_flow_to_router_and_experts():
    cfg = _cfg()
    key = jax.random.PRNGKey(2)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model))

    def loss(p):
        out, aux = apply_moe(p, x, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["experts"]["w_gate"]))) > 0


def _check_capacity_formula(T, E, k):
    k = min(k, E)
    cfg = _cfg(E, k, cf=1.25)
    C = _capacity(T, cfg)
    assert C % 8 == 0 and C >= 8
    assert C * E >= T * k            # cf ≥ 1 ⇒ total slots cover all assignments


@pytest.mark.parametrize("T,E,k", [
    (1, 2, 1), (512, 40, 8), (7, 3, 2), (64, 8, 2), (100, 16, 4),
])
def test_smoke_capacity_formula(T, E, k):
    """Deterministic replay of the capacity-formula property (no hypothesis)."""
    _check_capacity_formula(T, E, k)


if hypothesis is not None:
    @hypothesis.given(T=st.integers(1, 512), E=st.integers(2, 40),
                      k=st.integers(1, 8))
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_property_capacity_formula(T, E, k):
        _check_capacity_formula(T, E, k)
else:
    def test_property_suite_requires_hypothesis():
        pytest.importorskip("hypothesis")
