"""Equivalence harness for SUMO's state layouts and update engines.

The optimizer has two independent switches — the update engine
(``bucketed=True`` stacked buckets vs the per-leaf reference) and the state
layout (``state_layout="bucket"`` per-bucket stacked Q/M/prev_norm vs
``"leaf"`` param-tree mirrors). All four combinations must be THE SAME
optimizer, bit for bit, across a subspace-refresh boundary; layout
conversion must be a lossless round-trip; and a checkpoint written in one
layout must restore into the other and continue training as if nothing
happened. This module pins all of that against the per-leaf/leaf-layout
reference.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SumoConfig,
    convert_sumo_state,
    sumo,
    sumo_optimizer,
    sumo_state_layout,
)
from repro.train import CheckpointManager

IS_NONE = lambda x: x is None


def _tree_2d(key):
    """Same-shape 2D leaves + a wide singleton: two buckets."""
    return {
        "a": jax.random.normal(key, (64, 32)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (64, 32)),
        "wide": jax.random.normal(jax.random.fold_in(key, 2), (16, 48)),
    }


def _tree_experts(key):
    """(E, m, n) expert stack sharing a bucket with 2D leaves."""
    return {
        "experts": jax.random.normal(key, (3, 64, 32)),
        "w": jax.random.normal(jax.random.fold_in(key, 1), (64, 32)),
        "deep": jax.random.normal(jax.random.fold_in(key, 2), (2, 2, 16, 8)),
    }


def _tree_mixed(key):
    """None/fallback leaves + transpose partners sharing a canonical bucket."""
    return {
        "wq": jax.random.normal(key, (64, 32)),
        "w_down": jax.random.normal(jax.random.fold_in(key, 1), (32, 64)),
        "experts": jax.random.normal(jax.random.fold_in(key, 2), (2, 32, 64)),
        "masked": None,
        "wide": jax.random.normal(jax.random.fold_in(key, 3), (16, 48)),
    }


TREES = {"2d": _tree_2d, "experts": _tree_experts, "mixed": _tree_mixed}


def _assert_tree_equal(a, b, msg=""):
    fa = jax.tree_util.tree_flatten_with_path(a, is_leaf=IS_NONE)[0]
    fb = jax.tree_util.tree_flatten_with_path(b, is_leaf=IS_NONE)[0]
    assert len(fa) == len(fb)
    for (pa, la), (_, lb) in zip(fa, fb):
        if la is None or lb is None:
            assert la is None and lb is None, f"{msg}: None mismatch at {pa}"
            continue
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb), err_msg=f"{msg}: {pa}")


def _run(cfg, params, grads, steps, partial=None):
    tx = sumo(0.01, cfg)
    state = tx.init(params)
    updates = []
    for _ in range(steps):
        u, state = tx.update(grads, state, partial if partial is not None else params)
        updates.append(u)
    return updates, state


# ---------------------------------------------------------------------------
# engine × layout equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tree_name", sorted(TREES))
@pytest.mark.parametrize(
    "bucketed,layout",
    [(True, "leaf"), (True, "bucket"), (False, "bucket")],
    ids=["bucketed-leaf", "bucketed-bucket", "per_leaf-bucket"],
)
def test_layout_engine_equivalence(tree_name, bucketed, layout):
    """Every engine/layout combination is bit-identical to the per-leaf
    reference over 5 steps with update_freq=3 — i.e. across the K−1 → K →
    K+1 refresh boundary (refreshes fire at steps 0 and 3)."""
    params = TREES[tree_name](jax.random.PRNGKey(0))
    grads = jax.tree_util.tree_map(
        lambda x: None if x is None else x * 0.01, params, is_leaf=IS_NONE)
    cfg = SumoConfig(rank=8, update_freq=3, weight_decay=0.05,
                     bucketed=bucketed, state_layout=layout)
    ref_cfg = dataclasses.replace(cfg, bucketed=False, state_layout="leaf")

    us, state = _run(cfg, params, grads, steps=5)
    ref_us, ref_state = _run(ref_cfg, params, grads, steps=5)

    for step, (u, ru) in enumerate(zip(us, ref_us)):
        _assert_tree_equal(u, ru, msg=f"step {step} deltas")
    # states compare in the leaf layout (conversion is pure data movement)
    state_leaf = (convert_sumo_state(state, params, cfg, "leaf")
                  if sumo_state_layout(state) == "bucket" else state)
    _assert_tree_equal(state_leaf.Q, ref_state.Q, msg="Q")
    _assert_tree_equal(state_leaf.M, ref_state.M, msg="M")
    _assert_tree_equal(state_leaf.prev_norm, ref_state.prev_norm, msg="prev_norm")


@pytest.mark.parametrize("tree_name", sorted(TREES))
def test_state_layout_round_trip(tree_name):
    """leaf -> bucket -> leaf conversion is the identity, bit for bit, on a
    state that has actually trained (non-zero Q/M/prev_norm)."""
    params = TREES[tree_name](jax.random.PRNGKey(1))
    grads = jax.tree_util.tree_map(
        lambda x: None if x is None else x * 0.01, params, is_leaf=IS_NONE)
    cfg = SumoConfig(rank=8, update_freq=2, state_layout="leaf")
    _, state = _run(cfg, params, grads, steps=3)
    assert sumo_state_layout(state) == "leaf"

    bucket = convert_sumo_state(state, params, cfg, "bucket")
    assert sumo_state_layout(bucket) == "bucket"
    # canonical keys: every Q stack is (B, long, r) with long >= short
    for k, q in bucket.Q.items():
        long_d, short_d = map(int, k.split("x"))
        assert long_d >= short_d
        assert q.shape[1] == long_d and bucket.M[k].shape[2] == short_d
        assert bucket.prev_norm[k].shape == (q.shape[0],)

    back = convert_sumo_state(bucket, params, cfg, "leaf")
    _assert_tree_equal(back.Q, state.Q, msg="Q round-trip")
    _assert_tree_equal(back.M, state.M, msg="M round-trip")
    _assert_tree_equal(back.prev_norm, state.prev_norm, msg="prev_norm round-trip")
    # converting to the layout a state is already in is a no-op
    assert convert_sumo_state(bucket, params, cfg, "bucket") is bucket


def test_bucket_init_matches_converted_leaf_init():
    """init in bucket layout == convert(init in leaf layout): the plan is a
    pure function of the shapes, so the two never disagree."""
    params = _tree_mixed(jax.random.PRNGKey(2))
    cfg = SumoConfig(rank=8, state_layout="bucket")
    s_bucket = sumo(0.01, cfg).init(params)
    s_leaf = sumo(0.01, dataclasses.replace(cfg, state_layout="leaf")).init(params)
    conv = convert_sumo_state(s_leaf, params, cfg, "bucket")
    _assert_tree_equal(s_bucket.Q, conv.Q)
    _assert_tree_equal(s_bucket.M, conv.M)
    _assert_tree_equal(s_bucket.prev_norm, conv.prev_norm)


# ---------------------------------------------------------------------------
# weight decay in mixed buckets (regression: decay must be per-member)
# ---------------------------------------------------------------------------

def test_weight_decay_mixed_orientation_bucket():
    """A canonical bucket mixing a leaf with its transpose partner — one with
    a param, one without — must decay exactly like the per-leaf engine: the
    stacked W transposes with G, and members without a param contribute a
    zero decay term (not a dropped one)."""
    key = jax.random.PRNGKey(3)
    params = {"w_up": jax.random.normal(key, (16, 64)),
              "w_down": jax.random.normal(jax.random.fold_in(key, 1), (64, 16))}
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    partial = {"w_up": params["w_up"], "w_down": None}
    cfg = SumoConfig(rank=4, update_freq=2, weight_decay=0.1, bucketed=True)

    for layout in ("leaf", "bucket"):
        c = dataclasses.replace(cfg, state_layout=layout)
        u_b, _ = _run(c, params, grads, 2, partial=partial)
        u_l, _ = _run(dataclasses.replace(c, bucketed=False, state_layout="leaf"),
                      params, grads, 2, partial=partial)
        for step, (ub, ul) in enumerate(zip(u_b, u_l)):
            _assert_tree_equal(ub, ul, msg=f"layout={layout} step={step}")

    # and the decay really bites: the param-carrying leaf differs from a
    # decay-free run, the param-less one doesn't
    u_wd, _ = _run(cfg, params, grads, 1, partial=partial)
    u_nw, _ = _run(dataclasses.replace(cfg, weight_decay=0.0), params, grads, 1,
                   partial=partial)
    assert float(jnp.max(jnp.abs(u_wd[0]["w_up"] - u_nw[0]["w_up"]))) > 0
    np.testing.assert_array_equal(np.asarray(u_wd[0]["w_down"]),
                                  np.asarray(u_nw[0]["w_down"]))


def test_weight_decay_masked_param_carrier():
    """When the only param-carrying member of a bucket is masked out (None in
    the init tree and the grads, the multi_transform contract), the remaining
    member must still match the per-leaf engine: no decay for it — its param
    is absent — rather than the whole bucket silently inheriting or dropping
    decay."""
    key = jax.random.PRNGKey(4)
    real_a = jax.random.normal(key, (32, 16))
    b = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    masked = {"a": None, "b": b}                  # what SUMO was init'ed with
    grads = {"a": None, "b": b * 0.01}
    partial = {"a": real_a, "b": None}            # the param-carrier is masked
    cfg = SumoConfig(rank=4, update_freq=2, weight_decay=0.1, bucketed=True)
    u_b, _ = _run(cfg, masked, grads, 2, partial=partial)
    u_l, _ = _run(dataclasses.replace(cfg, bucketed=False, state_layout="leaf"),
                  masked, grads, 2, partial=partial)
    for step, (ub, ul) in enumerate(zip(u_b, u_l)):
        assert ub["a"] is None and ul["a"] is None
        np.testing.assert_array_equal(np.asarray(ub["b"]), np.asarray(ul["b"]),
                                      err_msg=f"step {step}")
    # and "b" matches a decay-free run exactly: its param is absent, so the
    # bucket-level W stacking must not leak "a"'s decay onto it
    u_nw, _ = _run(dataclasses.replace(cfg, weight_decay=0.0), masked, grads, 1,
                   partial=partial)
    np.testing.assert_array_equal(np.asarray(u_b[0]["b"]), np.asarray(u_nw[0]["b"]))


@pytest.mark.parametrize("bucketed", [True, False], ids=["bucketed", "per_leaf"])
def test_bucket_state_rejects_inconsistent_mask(bucketed):
    """Bucket-resident state is keyed by the static plan: a gradient tree
    whose None mask changes a bucket's slot count fails loudly under BOTH
    engines (the leaf layout would silently drop the masked leaf's state).
    A mask drift that permutes same-shaped leaves is outside what positional
    slots can detect — the contract is a static mask, as under
    multi_transform."""
    key = jax.random.PRNGKey(7)
    params = {"a": jax.random.normal(key, (32, 16)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (32, 16))}
    tx = sumo(0.01, SumoConfig(rank=4, state_layout="bucket", bucketed=bucketed))
    state = tx.init(params)
    with pytest.raises(ValueError, match="bucket 32x16"):
        tx.update({"a": None, "b": params["b"] * 0.01}, state, params)


# ---------------------------------------------------------------------------
# checkpoint migration (per-leaf ckpt -> bucket template and back)
# ---------------------------------------------------------------------------

def _ckpt_params(key):
    """Realistic tree: fallback leaves (embed/norm) mask to None under
    sumo_optimizer's multi_transform — the __none__ checkpoint encoding —
    plus a transpose pair that shares a canonical bucket."""
    return {
        "embed_tokens": jax.random.normal(key, (50, 8)),
        "blocks": {
            "wq": jax.random.normal(jax.random.fold_in(key, 1), (16, 16)),
            "w_up": jax.random.normal(jax.random.fold_in(key, 2), (16, 32)),
            "w_down": jax.random.normal(jax.random.fold_in(key, 3), (32, 16)),
        },
        "final_norm": {"norm_scale": jnp.ones((16,))},
    }


@pytest.mark.parametrize("src,dst", [("leaf", "bucket"), ("bucket", "leaf")],
                         ids=["leaf->bucket", "bucket->leaf"])
def test_checkpoint_layout_migration_resumes_seamlessly(tmp_path, src, dst):
    """Save SUMO state in one layout, restore into a template built with the
    other, resume 2 steps: bit-identical to an uninterrupted run. Covers the
    manifest round-trip and the __none__ masked-leaf encoding."""
    params = _ckpt_params(jax.random.PRNGKey(5))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    mk = lambda layout: sumo_optimizer(
        0.01, params,
        SumoConfig(rank=4, update_freq=3, weight_decay=0.01, state_layout=layout))
    tx_s, tx_d = mk(src), mk(dst)

    # uninterrupted reference in the destination layout (5 steps: the resume
    # point, step 3, is a refresh step)
    sd = tx_d.init(params)
    ref_us = []
    for _ in range(5):
        u, sd = tx_d.update(grads, sd, params)
        ref_us.append(u)

    ss = tx_s.init(params)
    for _ in range(3):
        _, ss = tx_s.update(grads, ss, params)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"params": params, "opt_state": ss}, extra={"layout": src})

    # masked leaves are recorded as __none__ markers: in the leaf layout the
    # SUMO state itself has them (bucket layout simply omits masked leaves
    # from the stacks); the AdamW fallback state always does
    import numpy as _np
    with _np.load(os.path.join(mgr._step_dir(3), "state.npz")) as z:
        none_keys = [k for k in z.files if k.startswith("__none__")]
    assert none_keys
    if src == "leaf":
        assert any("embed_tokens" in k and "|Q|" in k for k in none_keys)

    template = {"params": params, "opt_state": tx_d.init(params)}
    restored, manifest = mgr.restore(template)
    assert manifest["step"] == 3 and manifest["layout"] == src

    sd2 = restored["opt_state"]
    for i in (3, 4):
        u, sd2 = tx_d.update(grads, sd2, params)
        _assert_tree_equal(u, ref_us[i], msg=f"resumed step {i}")


def test_checkpoint_same_layout_unaffected(tmp_path):
    """No migration when layouts agree — bucket-resident state round-trips
    through save/restore directly."""
    params = _ckpt_params(jax.random.PRNGKey(6))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    tx = sumo_optimizer(0.01, params, SumoConfig(rank=4, update_freq=2,
                                                 state_layout="bucket"))
    s = tx.init(params)
    for _ in range(2):
        _, s = tx.update(grads, s, params)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"opt_state": s})
    restored, _ = mgr.restore({"opt_state": tx.init(params)})
    _assert_tree_equal(restored["opt_state"]["matrix"].Q, s["matrix"].Q)
    _assert_tree_equal(restored["opt_state"]["matrix"].M, s["matrix"].M)


def test_checkpoint_missing_leaf_still_raises(tmp_path):
    """Migration only fires for layout mismatches: a genuinely missing leaf
    keeps raising KeyError."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(KeyError):
        mgr.restore({"w": jnp.zeros((4, 4)), "extra": jnp.zeros((2,))})
