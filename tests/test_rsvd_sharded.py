"""Distributed rSVD + the 2D-mesh (data × model) SUMO bucket update.

The in-process tests need 8 devices, so they skip under the default
single-device tier-1 run and execute via either (a) the slow subprocess
wrapper at the bottom or (b) the second tier-1 invocation in
tools/run_tier1.sh, which re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

What is pinned here (the ISSUE-4 acceptance criteria):
  * the distributed range finder / rSVD on row-sharded matrices match the
    gathered single-device reference: subspace overlap ≥ 1-1e-5, identical
    singular values to fp32 tolerance, orthonormal output, and no NaNs on
    rank-deficient input (zero matrices — the bucketed engine's pad slots);
  * SUMO on a (data=2, model=4) mesh — B over `data`, each matrix's long dim
    over `model` — matches the single-device engine: deltas/state allclose,
    per-matrix basis overlap ≥ 1-1e-5, for divisible, ragged, expert-stack
    and B=1 (embed/lm_head-shaped) buckets, cadence-only and adaptive;
  * `model=1` meshes stay BIT-identical to the 1D path (the CholeskyQR2
    refresh only runs when matrices are actually sharded);
  * the compiled 2D update moves no (long × short)-sized collective: every
    all-reduce is an r-width panel; the only large transfers are the
    explicit delta all-gathers.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


# ---------------------------------------------------------------------------
# kernel level: core.rsvd axis_name path
# ---------------------------------------------------------------------------

@needs_8_devices
def test_distributed_range_finder_matches_gathered():
    from repro.core import randomized_range_finder, subspace_overlap

    mesh = _mesh24()
    key = jax.random.PRNGKey(0)
    G = jax.random.normal(key, (256, 32))
    Q_ref = randomized_range_finder(G, key, rank=8)
    f = shard_map(
        lambda g, k: randomized_range_finder(g, k, 8, axis_name="model"),
        mesh=mesh, in_specs=(P("model", None), P()),
        out_specs=P("model", None), check_rep=False)
    Q = f(G, key)
    # orthonormal to fp32 roundoff despite never gathering the panel
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(8), atol=1e-5)
    assert float(subspace_overlap(Q_ref, Q)) >= 1.0 - 1e-5


@needs_8_devices
def test_distributed_rsvd_matches_gathered():
    from repro.core import randomized_svd, subspace_overlap

    mesh = _mesh24()
    key = jax.random.PRNGKey(1)
    G = jax.random.normal(key, (512, 24))
    U_ref, s_ref, Vt_ref = randomized_svd(G, key, rank=6)
    U, s, Vt = shard_map(
        lambda g, k: randomized_svd(g, k, 6, axis_name="model"),
        mesh=mesh, in_specs=(P("model", None), P()),
        out_specs=(P("model", None), P(), P()), check_rep=False)(G, key)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-5)
    assert float(subspace_overlap(U_ref, U)) >= 1.0 - 1e-5
    # right factors agree up to the same fp32 tolerance (no sign ambiguity:
    # both factorizations produce U·s·Vt for the SAME G)
    np.testing.assert_allclose(np.asarray(U @ (s[:, None] * Vt)),
                               np.asarray(U_ref @ (s_ref[:, None] * Vt_ref)),
                               atol=1e-3)


@needs_8_devices
def test_distributed_range_finder_rank_deficient_is_finite():
    """Zero matrices (the sharded bucket path's masked pad slots) must come
    back as finite zeros, not the NaNs an unshifted Cholesky would give."""
    from repro.core import randomized_range_finder

    mesh = _mesh24()
    f = shard_map(
        lambda g, k: randomized_range_finder(g, k, 4, axis_name="model"),
        mesh=mesh, in_specs=(P("model", None), P()),
        out_specs=P("model", None), check_rep=False)
    Q = f(jnp.zeros((128, 16)), jax.random.PRNGKey(2))
    assert bool(jnp.all(jnp.isfinite(Q)))
    assert float(jnp.linalg.norm(Q)) == 0.0


# ---------------------------------------------------------------------------
# engine level: the 2D shard_map bucket update
# ---------------------------------------------------------------------------

def _params_2d(key):
    """Ragged B=5 bucket of (64, 32) (long 64 % 4 == 0), an expert stack
    (3, 80, 24), and a B=1 wide leaf (16, 128) — transposed into canonical
    (128, 16), the embed/lm_head-shaped singleton the model axis exists
    for."""
    p = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (64, 32))
         for i in range(5)}
    p["experts"] = jax.random.normal(jax.random.fold_in(key, 50), (3, 80, 24))
    p["wide"] = jax.random.normal(jax.random.fold_in(key, 99), (16, 128))
    return p


def _run(tx, params, grads, steps):
    state = tx.init(params)
    out = []
    for _ in range(steps):
        u, state = tx.update(grads, state, params)
        out.append(u)
    return out, state


@needs_8_devices
@pytest.mark.parametrize("refresh_quality", [0.0, 0.5],
                         ids=["cadence-only", "adaptive"])
def test_2d_mesh_matches_single_device(refresh_quality):
    """5 steps with update_freq=3 (refresh boundary at step 3): deltas and
    state allclose against the unsharded engine, and every per-matrix basis
    overlaps its reference ≥ 1-1e-5. Not bit-parity: the model-sharded
    refresh orthogonalizes via CholeskyQR2 instead of thin QR — but the
    update itself is within-subspace-rotation invariant (delta = Q·orth(M)
    with M rotated consistently), so deltas agree to fp32 accumulation."""
    from repro.core import SumoConfig, subspace_overlap, sumo

    mesh = _mesh24()
    params = _params_2d(jax.random.PRNGKey(0))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=3, weight_decay=0.05,
                     refresh_quality=refresh_quality)

    us, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 5)
    up, sp = _run(sumo(0.01, cfg), params, grads, 5)

    for step, (a, b) in enumerate(zip(us, up)):
        for k in params:
            np.testing.assert_allclose(
                np.asarray(a[k]), np.asarray(b[k]), atol=1e-5,
                err_msg=f"step {step} leaf {k}")
    for bk in ss.Q:
        Qs, Qp = np.asarray(ss.Q[bk]), np.asarray(sp.Q[bk])
        assert Qs.shape == Qp.shape       # state itself is NOT padded
        for i in range(Qs.shape[0]):
            ov = float(subspace_overlap(jnp.asarray(Qs[i]),
                                        jnp.asarray(Qp[i])))
            assert ov >= 1.0 - 1e-5, (bk, i, ov)
        np.testing.assert_allclose(np.asarray(ss.prev_norm[bk]),
                                   np.asarray(sp.prev_norm[bk]), atol=1e-5)
        # M lives in basis coordinates, where the small SVD's per-column
        # sign choice is input-dependent — the LIFTED moment QM is the
        # basis-free quantity and must agree.
        np.testing.assert_allclose(np.asarray(ss.Q[bk] @ ss.M[bk]),
                                   np.asarray(sp.Q[bk] @ sp.M[bk]),
                                   atol=1e-4)


@needs_8_devices
def test_2d_mesh_telemetry_close_to_unsharded():
    """SpectralStats from the 2D path agree with the unsharded engine's to
    fp32 tolerance (relative for κ — a squared ratio)."""
    from repro.core import SumoConfig, sumo

    mesh = _mesh24()
    params = _params_2d(jax.random.PRNGKey(4))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=3, telemetry=True)
    _, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 4)
    _, sp = _run(sumo(0.01, cfg), params, grads, 4)
    assert set(ss.stats) == set(sp.stats) == {"64x32", "80x24", "128x16"}
    for bucket in ss.stats:
        for field, a, b in zip(ss.stats[bucket]._fields, ss.stats[bucket],
                               sp.stats[bucket]):
            np.testing.assert_allclose(
                np.asarray(a, np.float64), np.asarray(b, np.float64),
                rtol=1e-3, atol=1e-5, err_msg=f"{bucket}.{field}")


@needs_8_devices
def test_model_axis_of_one_stays_bit_identical():
    """A mesh WITH a model axis of size 1 must take the existing 1D path
    bit-exactly — the distributed refresh only runs when matrices are
    actually sharded."""
    from repro.core import SumoConfig, sumo

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    params = _params_2d(jax.random.PRNGKey(3))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=3, weight_decay=0.05)
    us, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 5)
    up, sp = _run(sumo(0.01, cfg), params, grads, 5)
    for step, (a, b) in enumerate(zip(us, up)):
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(a[k]), np.asarray(b[k]),
                err_msg=f"step {step} leaf {k}")
    for fa, fb in zip(jax.tree_util.tree_leaves(ss),
                      jax.tree_util.tree_leaves(sp)):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


@needs_8_devices
def test_2d_mesh_no_full_matrix_collectives():
    """Compile the 2D update with state placed by opt_state_specs
    (Q: P(data, model, None)) and audit the optimized HLO: every all-reduce
    is an r-width panel (some dim ≤ l = rank + oversample), the all-gathers
    are exactly the delta gathers, and nothing else moves — refresh branch
    included (the conditional's collectives are r-width too)."""
    from repro.analysis.collectives import (
        assert_budget,
        bucket_collective_plan,
        delta_bytes,
        steady_2d_budget,
    )
    from repro.core import SumoConfig, sumo
    from repro.parallel import opt_state_specs

    mesh = _mesh24()
    key = jax.random.PRNGKey(1)
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (256, 16))
              for i in range(4)}
    params["wide"] = jax.random.normal(jax.random.fold_in(key, 9), (16, 128))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    rank, over = 4, 4
    tx = sumo(0.01, SumoConfig(rank=rank, update_freq=4, weight_decay=0.05,
                               rsvd_oversample=over), mesh=mesh)
    state = tx.init(params)
    named = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    st_sh = named(opt_state_specs(state, mesh))
    assert st_sh.Q["256x16"].spec == P("data", "model", None)
    assert st_sh.Q["128x16"].spec == P(None, "model", None)   # B=1 singleton
    rep = NamedSharding(mesh, P())
    g_sh = jax.tree_util.tree_map(lambda _: rep, grads)
    compiled = jax.jit(
        lambda g, s, p: tx.update(g, s, p),
        in_shardings=(g_sh, st_sh, g_sh),
    ).lower(grads, state, params).compile()
    txt = compiled.as_text()

    # The declarative budget (shared with tools/lint_static.py and
    # benchmarks/step_time.py) replaces the old hand-rolled regex walk: the
    # bucket plan derives the legitimate gather shapes from the resident
    # state, the budget allows only those plus r-width panel all-reduces,
    # and audit_hlo walks the optimized HLO — cond branches included.
    plan = bucket_collective_plan(state, mesh)
    budget = steady_2d_budget(plan, rank_plus_over=rank + over,
                              data_shards=int(mesh.shape["data"]))
    report = assert_budget(txt, budget)
    kinds = {e["op"] for e in report.collectives}
    assert kinds == {"all-reduce", "all-gather"}, kinds
    # plan mirrors the engine: both buckets shard on a 2D mesh (the B=1
    # singleton included), none of them pad
    assert {e.key: e.b_padded for e in plan} == {"256x16": 4, "128x16": 1}
    assert delta_bytes(plan) == sum(
        int(np.prod(v.shape)) * 4 for v in params.values())


@needs_8_devices
def test_2d_mesh_under_jit_close_to_eager():
    """jit with 2D-sharded state in/out stays numerically equivalent to the
    eager 2D path (across modes XLA fusion moves the last ulp)."""
    from repro.core import SumoConfig, sumo
    from repro.parallel import opt_state_specs

    mesh = _mesh24()
    params = _params_2d(jax.random.PRNGKey(2))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    tx = sumo(0.01, SumoConfig(rank=8, update_freq=4), mesh=mesh)
    state = tx.init(params)
    named = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    st_sh = named(opt_state_specs(state, mesh))
    rep = NamedSharding(mesh, P())
    g_sh = jax.tree_util.tree_map(lambda _: rep, grads)
    u_j, s_j = jax.jit(lambda g, s, p: tx.update(g, s, p),
                       in_shardings=(g_sh, st_sh, g_sh))(grads, state, params)
    u_e, s_e = tx.update(grads, state, params)
    for k in params:
        np.testing.assert_allclose(np.asarray(u_j[k]), np.asarray(u_e[k]),
                                   atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(s_j),
                    jax.tree_util.tree_leaves(s_e)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# ragged long dims: edge-padded 2D path (ISSUE-5)
# ---------------------------------------------------------------------------

def _params_ragged(key):
    """Every bucket's long dim is RAGGED on model=4: a B=3 bucket of
    (102, 16) (ragged over data=2 as well), an expert stack (2, 50, 8)
    (50 % 4 == 2), and a wide B=1 leaf (12, 102) — canonical (100, 12), the
    embed/lm_head-shaped singleton. No bucket may fall back to the
    replicated-long 1D path."""
    p = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (102, 16))
         for i in range(3)}
    p["experts"] = jax.random.normal(jax.random.fold_in(key, 50), (2, 50, 8))
    p["wide"] = jax.random.normal(jax.random.fold_in(key, 99), (12, 102))
    return p


@needs_8_devices
@pytest.mark.parametrize("refresh_quality", [0.0, 0.5],
                         ids=["cadence-only", "adaptive"])
def test_ragged_long_2d_matches_single_device(refresh_quality):
    """long % model != 0 buckets take the 2D sharded path via edge-padded
    zero rows: deltas/state allclose against the unsharded engine, per-matrix
    basis overlap ≥ 1-1e-5, the stored Q is padded to the next model-axis
    multiple, and its pad rows stay EXACTLY zero across refreshes (the
    inertness invariant core.rsvd documents)."""
    from repro.core import SumoConfig, padded_long, subspace_overlap, sumo

    mesh = _mesh24()
    params = _params_ragged(jax.random.PRNGKey(0))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=6, update_freq=3, weight_decay=0.05,
                     refresh_quality=refresh_quality)

    us, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 5)
    up, sp = _run(sumo(0.01, cfg), params, grads, 5)

    for step, (a, b) in enumerate(zip(us, up)):
        for k in params:
            np.testing.assert_allclose(
                np.asarray(a[k]), np.asarray(b[k]), atol=1e-5,
                err_msg=f"step {step} leaf {k}")
    for bk, Qp in sp.Q.items():
        true_long = Qp.shape[1]
        # the stored stack carries the edge-padded long dim...
        assert ss.Q[bk].shape[1] == padded_long(true_long, 4) != true_long
        Qs = np.asarray(ss.Q[bk])
        # ...whose pad rows are exactly zero after 5 steps incl. refreshes
        assert float(np.abs(Qs[:, true_long:]).max()) == 0.0, bk
        for i in range(Qs.shape[0]):
            ov = float(subspace_overlap(jnp.asarray(Qs[i, :true_long]),
                                        jnp.asarray(np.asarray(Qp)[i])))
            assert ov >= 1.0 - 1e-5, (bk, i, ov)
        np.testing.assert_allclose(np.asarray(ss.prev_norm[bk]),
                                   np.asarray(sp.prev_norm[bk]), atol=1e-5)
        # basis-free lifted moment agrees (pad rows of Q kill pad terms)
        np.testing.assert_allclose(
            np.asarray(ss.Q[bk][:, :true_long] @ ss.M[bk]),
            np.asarray(Qp @ sp.M[bk]), atol=1e-4)


@needs_8_devices
def test_ragged_long_model1_stays_bit_identical():
    """Ragged params on a (data=8, model=1) mesh: no padding, and the 1D
    path bit-identical to the unsharded engine — the acceptance pin that
    edge-padding never perturbs the model=1 regime."""
    from repro.core import SumoConfig, sumo

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    params = _params_ragged(jax.random.PRNGKey(3))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=6, update_freq=3, weight_decay=0.05)
    us, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 5)
    up, sp = _run(sumo(0.01, cfg), params, grads, 5)
    for step, (a, b) in enumerate(zip(us, up)):
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(a[k]), np.asarray(b[k]),
                err_msg=f"step {step} leaf {k}")
    for fa, fb in zip(jax.tree_util.tree_leaves(ss),
                      jax.tree_util.tree_leaves(sp)):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


@needs_8_devices
def test_ragged_long_telemetry_matches_1d_probes():
    """SpectralStats under long-dim padding pinned against the 1D engine's
    probes: the pad rows contribute exactly zero to every psum feeding the
    replicated stats, so energy capture / ortho residual / norms must not
    be diluted (ISSUE-5 stat-reduction audit)."""
    from repro.core import SumoConfig, sumo

    mesh = _mesh24()
    params = _params_ragged(jax.random.PRNGKey(4))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=6, update_freq=3, telemetry=True)
    _, ss = _run(sumo(0.01, cfg, mesh=mesh), params, grads, 4)
    _, sp = _run(sumo(0.01, cfg), params, grads, 4)
    assert set(ss.stats) == set(sp.stats) == {"102x16", "50x8", "102x12"}
    for bucket in ss.stats:
        for field, a, b in zip(ss.stats[bucket]._fields, ss.stats[bucket],
                               sp.stats[bucket]):
            np.testing.assert_allclose(
                np.asarray(a, np.float64), np.asarray(b, np.float64),
                rtol=1e-3, atol=1e-5, err_msg=f"{bucket}.{field}")


@needs_8_devices
def test_ragged_long_no_full_matrix_collectives():
    """The edge-padded 2D update compiles with the same collective
    discipline as divisible buckets: opt_state_specs places the PADDED Q
    over `model`, every all-reduce is an r-width panel, and the only
    all-gathers are the (padded-row) delta gathers."""
    from repro.analysis.collectives import (
        assert_budget,
        bucket_collective_plan,
        pad_overhead_frac,
        steady_2d_budget,
    )
    from repro.core import SumoConfig, padded_long, sumo
    from repro.parallel import opt_state_specs

    mesh = _mesh24()
    key = jax.random.PRNGKey(5)
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), (102, 16))
              for i in range(4)}
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    rank, over = 4, 4
    tx = sumo(0.01, SumoConfig(rank=rank, update_freq=4, weight_decay=0.05,
                               rsvd_oversample=over), mesh=mesh)
    state = tx.init(params)
    lp = padded_long(102, 4)                      # 104
    assert state.Q["102x16"].shape == (4, lp, rank)
    named = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    st_sh = named(opt_state_specs(state, mesh))
    assert st_sh.Q["102x16"].spec == P("data", "model", None)
    rep = NamedSharding(mesh, P())
    g_sh = jax.tree_util.tree_map(lambda _: rep, grads)
    compiled = jax.jit(
        lambda g, s, p: tx.update(g, s, p),
        in_shardings=(g_sh, st_sh, g_sh),
    ).lower(grads, state, params).compile()
    txt = compiled.as_text()

    # Same declarative budget as the divisible case — the plan recovers the
    # padded-row gather shapes {(4, 104, 16), (2, 104, 16)} from the state's
    # padded Q stack and the true long dim in the bucket key, and the
    # per-instance width caps are what would catch a (B, long, short)
    # collective like the pre-fix pad-concat all-reduce.
    plan = bucket_collective_plan(state, mesh)
    [entry] = plan
    assert (entry.long, entry.long_padded, entry.b_padded) == (102, lp, 4)
    assert pad_overhead_frac(plan) == (4 * lp * 16 - 4 * 102 * 16) / (
        4 * 102 * 16)
    budget = steady_2d_budget(plan, rank_plus_over=rank + over,
                              data_shards=int(mesh.shape["data"]))
    report = assert_budget(txt, budget)
    kinds = {e["op"] for e in report.collectives}
    assert kinds == {"all-reduce", "all-gather"}, kinds


@needs_8_devices
def test_square_sketch_stays_finite_in_fused_step():
    """Regression: rank + oversample ≥ short dim (l == n, the square-Omega
    sketch) used to hit NaNs in the sharded refresh inside large fused
    programs — the Gram's old 1e-12 shift sat ~1000× below fp32 roundoff,
    so an unlucky κ(G·Omega)² tipped ``cholesky`` into a negative pivot
    once XLA re-associated the reductions. The sketch now uses G itself
    when it cannot reduce dimension, and the shifted-CholeskyQR2 lift is
    eps-scaled. 60×20 @ rank 32 on (data=1, model=8) — the exact shape
    class that NaN'd — must stay finite for many keys."""
    from repro.core import SumoConfig, sumo

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    cfg = SumoConfig(rank=32, update_freq=1)   # refresh every step
    for seed in range(6):
        k = jax.random.PRNGKey(seed)
        params = {f"w{i}": jax.random.normal(jax.random.fold_in(k, i),
                                             (60, 20)) * 0.01
                  for i in range(4)}
        grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
        tx = sumo(0.01, cfg, mesh=mesh)
        upd = jax.jit(lambda g, s, p: tx.update(g, s, p))
        state = tx.init(params)
        for _ in range(3):
            u, state = upd(grads, state, params)
        leaves = jax.tree_util.tree_leaves((u, state))
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves), seed


@needs_8_devices
def test_train_model_parallel_end_to_end():
    """launch-level wiring: TrainConfig.model_parallel=4 builds the
    (data=2, model=4) host mesh and the whole step consumes it — params via
    the Megatron specs, opt state via opt_state_specs (edge-padded SUMO
    buckets), batch over `data`, SUMO's 2D shard_map update — for a real
    smoke-model train run."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.train import TrainConfig, train

    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    tcfg = TrainConfig(optimizer="sumo", learning_rate=1e-2, rank=4,
                       update_freq=2, total_steps=3, attn_impl="chunked",
                       model_parallel=4, log_every=1000)
    res = train(arch, shape, tcfg, log_fn=lambda s: None)
    assert res.final_step == 3 and len(res.losses) == 3
    assert all(np.isfinite(l) for _, l in res.losses)


@pytest.mark.slow
@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="already running with 8 devices")
def test_subprocess_8_device_suite():
    """Run the in-process tests above on a forced 8-host-device CPU backend
    (the main pytest process must keep 1 device — see tests/conftest.py)."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         "tests/test_rsvd_sharded.py", "-k", "not subprocess"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
