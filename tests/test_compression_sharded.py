"""Compressed DP gradient exchange on a real multi-device mesh (ROADMAP
item 1's machine checks).

The tests need 8 devices, so they skip under the default single-device
tier-1 run and execute via the second tier-1 invocation in
tools/run_tier1.sh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

What is pinned here:
  * the shard_map exchange (``parallel.compression.make_dp_exchange_fn`` —
    the same ``exchange_shard`` the train step inlines) reproduces the
    simulated per-worker compress → mean-payload → decompress loop
    bit-for-bit, EF residuals included;
  * error feedback through the REAL collective: the running average of the
    decoded syncs converges to the exact full-gradient mean;
  * ``use_sketch=False`` reuses a resident SUMO-style Q verbatim across
    steps (in-span gradients exchange losslessly, same bases tree reused
    across a refresh boundary), and an all-zero Q leaf bootstraps to the
    seeded sketch instead of a zero fixed point;
  * the compiled exchange PASSES ``steady_dp_compressed_budget`` (the only
    collectives are the r×short pmeans) while the classic full-gradient
    pmean on the same tree FAILS it with the documented violation codes —
    the budget is falsifiable, not vacuous;
  * the HLO-measured all-reduce bytes ratio matches the byte-accurate
    ``dp_wire_plan``/``compression_ratio`` prediction;
  * ``train(..., dp_compress=True)`` runs end-to-end on the mesh for BOTH
    bases (sketch at model_parallel=1, sumo-q at model_parallel=2 across a
    refresh boundary) and tracks the uncompressed run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _mesh(model=1):
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(model=model)


def _tree(key, n_workers):
    """Worker-distinct grads: two eligible matrix leaves (one transposed
    orientation), one exact-path small matrix, one exact-path vector."""
    ks = jax.random.split(key, 4)
    mk = lambda k, shape: jax.random.normal(
        k, (n_workers,) + shape, jnp.float32)
    return {
        "wide": mk(ks[0], (24, 96)),    # long dim is n -> transposed view
        "tall": mk(ks[1], (96, 16)),
        "tiny": mk(ks[2], (8, 8)),      # below min_dim -> exact pmean
        "vec": mk(ks[3], (40,)),        # ndim < 2 -> exact pmean
    }


def _place(mesh, grads_stacked, state):
    from jax.sharding import NamedSharding, PartitionSpec as P
    stack = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    grads = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, stack), grads_stacked)
    state = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, stack if x.ndim > 0 else rep), state)
    return grads, state


def _simulate(grads_stacked, state, cfg, bases=None):
    """Reference: per-worker compress, python-mean of payloads, per-worker
    finalize — what the shard_map must equal."""
    from repro.parallel.compression import (
        CompressionState,
        compress_grads,
        finalize,
        step_bases,
    )

    n = next(iter(jax.tree_util.tree_leaves(grads_stacked))).shape[0]
    worker = lambda t, w: jax.tree_util.tree_map(
        lambda x: None if x is None else x[w], t,
        is_leaf=lambda x: x is None)
    template = worker(grads_stacked, 0)
    eff = step_bases(template, state.step, cfg, bases=bases)

    payloads, metas, tds = [], [], None
    for w in range(n):
        local = CompressionState(step=state.step, error=worker(state.error, w))
        p, m, tds = compress_grads(worker(grads_stacked, w), local, cfg,
                                   bases=eff)
        payloads.append(p)
        metas.append(m)
    # pmean semantics on a sub-f32 payload: XLA promotes the all-reduce to
    # f32 and rounds the mean back to the wire dtype — match it exactly
    payload_mean = jax.tree_util.tree_map(
        lambda *xs: (sum(x.astype(jnp.float32) for x in xs) / n
                     ).astype(xs[0].dtype), *payloads)
    decoded, errors = [], []
    for w in range(n):
        local = CompressionState(step=state.step, error=worker(state.error, w))
        g, ns = finalize(payload_mean, metas[w], tds, local, cfg, bases=eff)
        decoded.append(g)
        errors.append(ns.error)
    return decoded, errors


@needs_8_devices
@pytest.mark.parametrize("error_feedback", [True, False])
def test_exchange_matches_simulated_mean(error_feedback):
    """The real collective == the per-worker simulation, bit-for-bit: the
    decoded mean on every worker row AND each worker's next EF residual."""
    from repro.parallel import (
        CompressionConfig,
        init_worker_state,
        make_dp_exchange_fn,
    )

    mesh = _mesh()
    n = int(mesh.shape["data"])
    cfg = CompressionConfig(rank=8, min_dim=32, seed=3,
                            error_feedback=error_feedback)
    grads = _tree(jax.random.PRNGKey(0), n)
    state = init_worker_state(
        jax.tree_util.tree_map(lambda x: x[0], grads), cfg, n)
    grads_d, state_d = _place(mesh, grads, state)

    exchange = jax.jit(make_dp_exchange_fn(mesh, cfg))
    decoded, new_state = exchange(grads_d, state_d, None)
    ref_decoded, ref_errors = _simulate(grads, state, cfg)

    for w in range(n):
        got = jax.tree_util.tree_map(lambda x: np.asarray(x[w]), decoded)
        for k in grads:
            np.testing.assert_allclose(got[k], np.asarray(ref_decoded[w][k]),
                                       rtol=0, atol=1e-5)
        if error_feedback:
            for k in ("wide", "tall"):
                np.testing.assert_allclose(
                    np.asarray(new_state.error[k][w]),
                    np.asarray(ref_errors[w][k]), rtol=0, atol=1e-5)
    if not error_feedback:
        assert all(e is None for e in
                   jax.tree_util.tree_leaves(
                       new_state.error, is_leaf=lambda x: x is None))
    assert int(new_state.step) == 1


@needs_8_devices
def test_error_feedback_converges_to_exact_mean_on_collective():
    """EF through the real pmean: with fixed per-worker grads, the decoded
    syncs telescope — (Σ_t decoded + mean_w e_T) / T == the EXACT mean, to
    float tolerance, at every horizon — so the running average converges to
    the uncompressed fixed point at rate ||e_T|| / T (checked decreasing)."""
    from repro.parallel import (
        CompressionConfig,
        init_worker_state,
        make_dp_exchange_fn,
    )

    mesh = _mesh()
    n = int(mesh.shape["data"])
    cfg = CompressionConfig(rank=16, min_dim=32, seed=0)
    grads = _tree(jax.random.PRNGKey(7), n)
    exact = jax.tree_util.tree_map(
        lambda x: np.asarray(x, np.float64).mean(0), grads)
    state = init_worker_state(
        jax.tree_util.tree_map(lambda x: x[0], grads), cfg, n)
    grads_d, state_d = _place(mesh, grads, state)

    exchange = jax.jit(make_dp_exchange_fn(mesh, cfg))
    total = jax.tree_util.tree_map(lambda x: np.zeros(x.shape, np.float64),
                                   exact)
    steps = 40
    rel_err = {}
    for t in range(1, steps + 1):
        decoded, state_d = exchange(grads_d, state_d, None)
        mean0 = jax.tree_util.tree_map(
            lambda x: np.asarray(x[0], np.float64), decoded)
        total = jax.tree_util.tree_map(np.add, total, mean0)
        if t in (10, steps):
            rel_err[t] = {
                k: np.linalg.norm(total[k] / t - exact[k])
                / np.linalg.norm(exact[k]) for k in ("wide", "tall")}
    for k in ("wide", "tall"):
        # the telescoping identity, up to the bf16 wire: EF absorbs each
        # worker's LOCAL round-trip error exactly, but the pmean's final
        # round back to bf16 (one rounding of the MEAN per step) is outside
        # the telescope — it averages out at ~bf16 ulp / sqrt(T)
        resid = np.asarray(state_d.error[k], np.float64).mean(0)
        recon = (total[k] + resid) / steps
        np.testing.assert_allclose(recon, exact[k], atol=2e-3)
        # and the running average really closes on the exact mean
        assert rel_err[steps][k] < 0.6 * rel_err[10][k], (k, rel_err)
    # exact-path leaves were never compressed at all
    for k in ("tiny", "vec"):
        np.testing.assert_allclose(total[k] / steps, exact[k], atol=1e-5)


@needs_8_devices
def test_sumo_q_reuse_and_zero_basis_bootstrap():
    """use_sketch=False: a resident orthonormal Q is used verbatim — grads
    living in its span exchange LOSSLESSLY, and the same bases tree reused
    across steps (a refresh interval) keeps doing so; an all-zero Q leaf (a
    SUMO state before its first rSVD) falls back to the seeded sketch
    instead of collapsing the exchange to zero."""
    from repro.parallel import (
        CompressionConfig,
        init_worker_state,
        make_dp_exchange_fn,
    )

    mesh = _mesh()
    n = int(mesh.shape["data"])
    r = 6
    # exact payload: this test pins the BASIS algebra (lossless in-span
    # round trip), which bf16 wire quantization would mask
    cfg = CompressionConfig(rank=r, min_dim=32, seed=1, use_sketch=False,
                            payload_dtype="float32")
    key = jax.random.PRNGKey(11)
    kq, kc, kz = jax.random.split(key, 3)

    # "tall" gets a real resident basis; "wide" an all-zero one (pre-refresh)
    Q, _ = jnp.linalg.qr(jax.random.normal(kq, (96, r)))
    bases = {"wide": jnp.zeros((96, r)), "tall": Q,
             "tiny": None, "vec": None}
    # tall grads strictly inside span(Q); wide grads generic
    coeff = jax.random.normal(kc, (n, r, 16))
    tall = jnp.einsum("lr,nrs->nls", Q, coeff)
    grads = _tree(kz, n)
    grads = dict(grads, tall=tall)
    exact = jax.tree_util.tree_map(lambda x: np.asarray(x).mean(0), grads)

    state = init_worker_state(
        jax.tree_util.tree_map(lambda x: x[0], grads), cfg, n)
    grads_d, state_d = _place(mesh, grads, state)
    from jax.sharding import NamedSharding, PartitionSpec as P
    bases_d = jax.tree_util.tree_map(
        lambda q: None if q is None
        else jax.device_put(q, NamedSharding(mesh, P())),
        bases, is_leaf=lambda x: x is None)

    exchange = jax.jit(make_dp_exchange_fn(mesh, cfg))
    for step in range(3):          # the SAME bases tree across a "refresh"
        decoded, state_d = exchange(grads_d, state_d, bases_d)
        got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]), decoded)
        # in-span leaf: lossless through the resident Q, every step
        np.testing.assert_allclose(got["tall"], exact["tall"], atol=1e-4)
        # EF residual of a lossless leaf stays ~0
        assert float(jnp.linalg.norm(state_d.error["tall"])) < 1e-3
        # zero-Q leaf: sketch bootstrap, NOT a zero fixed point
        assert np.linalg.norm(got["wide"]) > 1e-3


@needs_8_devices
def test_budget_passes_and_full_pmean_fails():
    """The compiled exchange satisfies ``steady_dp_compressed_budget`` (the
    named machine check of the wire claim), and the budget is FALSIFIABLE:
    the classic full-gradient pmean on the same tree violates it with the
    documented codes (shape-not-allowed + op-bytes-exceeded)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.analysis.collectives import (
        audit_hlo,
        steady_dp_compressed_budget,
    )
    from repro.parallel import (
        CompressionConfig,
        dp_wire_plan,
        init_worker_state,
        make_dp_exchange_fn,
    )

    mesh = _mesh()
    n = int(mesh.shape["data"])
    cfg = CompressionConfig(rank=8, min_dim=32)
    grads = _tree(jax.random.PRNGKey(2), n)
    template = jax.tree_util.tree_map(lambda x: x[0], grads)
    state = init_worker_state(template, cfg, n)
    grads_d, state_d = _place(mesh, grads, state)

    plan = dp_wire_plan(template, cfg)
    budget = steady_dp_compressed_budget(plan)

    exchange = jax.jit(make_dp_exchange_fn(mesh, cfg))
    hlo = exchange.lower(grads_d, state_d, None).compile().as_text()
    report = audit_hlo(hlo, budget)
    assert report.ok, report.summary()
    # at least one all-reduce per plan entry actually happened (the audit
    # is not passing on an empty program)
    assert len(report.collectives) >= sum(e.eligible for e in plan)

    full_mean = jax.jit(shard_map(
        lambda g: jax.tree_util.tree_map(
            lambda x: jax.lax.pmean(x[0], "data")[None], g),
        mesh, in_specs=(P("data"),), out_specs=P("data"), check_rep=False))
    hlo_full = full_mean.lower(grads_d).compile().as_text()
    bad = audit_hlo(hlo_full, budget)
    assert not bad.ok
    codes = {v.code for v in bad.violations}
    assert "shape-not-allowed" in codes, codes
    assert "op-bytes-exceeded" in codes, codes


@needs_8_devices
def test_hlo_wire_bytes_match_plan():
    """HLO-measured all-reduce bytes of the compiled exchange vs the
    full-gradient pmean == the byte-accurate ``compression_ratio`` — the
    plan and the partitioner cannot silently drift apart."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel import (
        CompressionConfig,
        compression_ratio,
        init_worker_state,
        make_dp_exchange_fn,
    )
    from repro.roofline.hlo_cost import analyze_hlo

    mesh = _mesh()
    n = int(mesh.shape["data"])
    cfg = CompressionConfig(rank=8, min_dim=32)
    grads = _tree(jax.random.PRNGKey(4), n)
    template = jax.tree_util.tree_map(lambda x: x[0], grads)
    state = init_worker_state(template, cfg, n)
    grads_d, state_d = _place(mesh, grads, state)

    exchange = jax.jit(make_dp_exchange_fn(mesh, cfg))
    full_mean = jax.jit(shard_map(
        lambda g: jax.tree_util.tree_map(
            lambda x: jax.lax.pmean(x[0], "data")[None], g),
        mesh, in_specs=(P("data"),), out_specs=P("data"), check_rep=False))
    meas = analyze_hlo(
        exchange.lower(grads_d, state_d, None).compile().as_text()
    ).collective_bytes
    meas_full = analyze_hlo(
        full_mean.lower(grads_d).compile().as_text()).collective_bytes
    ratio_meas = meas / meas_full
    # compiled HLO shows the bf16 payloads PROMOTED to f32 all-reduces
    # (XLA's all-reduce promotion on CPU/GPU) — compare against the plan's
    # hlo bytes; the true-wire ratio below stays strictly better
    from repro.parallel import dp_wire_plan, full_wire_bytes, hlo_wire_bytes
    plan = dp_wire_plan(template, cfg)
    ratio_plan = hlo_wire_bytes(plan) / full_wire_bytes(plan)
    # the ×2 trip multiplier cancels in the ratio; shapes are exact
    assert abs(ratio_meas - ratio_plan) / ratio_plan < 1e-6, (
        ratio_meas, ratio_plan)
    assert compression_ratio(template, cfg) <= ratio_plan


@needs_8_devices
def test_train_end_to_end_dp_compress_parity():
    """The REAL loop with --dp-compress: sketch basis at model_parallel=1
    and the sumo-q basis at model_parallel=2 (crossing a refresh boundary,
    so the resident-Q re-extraction path runs) both train, and the sketch
    run's final loss tracks the uncompressed run on the same data/seed."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.train import TrainConfig, train

    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("dpc", seq_len=32, global_batch=16, kind="train")
    steps = 10
    common = dict(optimizer="sumo", learning_rate=3e-3, rank=8,
                  update_freq=5, total_steps=steps, log_every=10**9)

    res_plain = train(arch, shape, TrainConfig(model_parallel=1, **common),
                      log_fn=lambda s: None)
    res_sketch = train(
        arch, shape,
        TrainConfig(model_parallel=1, dp_compress=True, dp_compress_rank=8,
                    dp_compress_min_dim=32, **common),
        log_fn=lambda s: None)
    res_sumoq = train(
        arch, shape,
        TrainConfig(model_parallel=2, dp_compress=True, dp_compress_rank=8,
                    dp_compress_min_dim=32, dp_compress_basis="sumo-q",
                    **common),
        log_fn=lambda s: None)

    for res in (res_plain, res_sketch, res_sumoq):
        losses = np.array([l for _, l in res.losses])
        assert np.all(np.isfinite(losses))
        # not diverging (10 smoke steps move the loss very little; the
        # strict ≥8×-wire-reduction parity gate lives in
        # benchmarks/convergence.py over a 60-step run)
        assert losses[-3:].mean() <= losses[:3].mean() + 0.02
    gap = abs(res_sketch.losses[-1][1] - res_plain.losses[-1][1])
    assert gap < 0.05 * abs(res_plain.losses[-1][1]), (
        res_sketch.losses[-1][1], res_plain.losses[-1][1])
