"""Bucketed SUMO update engine: plan construction, bit-parity with the
per-leaf reference engine, Pallas projection parity, and the one-refresh-cond-
per-bucket lowering guarantee."""
import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SumoConfig, build_bucket_plan, sumo


def _tree(key):
    """Mixed tree: two buckets — (64, 32) fed by 2D leaves + a 3D expert
    stack, and a wide (16, 48) singleton."""
    return {
        "a": jax.random.normal(key, (64, 32)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (64, 32)),
        "experts": jax.random.normal(jax.random.fold_in(key, 2), (3, 64, 32)),
        "wide": jax.random.normal(jax.random.fold_in(key, 3), (16, 48)),
    }


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

def test_bucket_plan_groups_by_matrix_shape():
    plan = build_bucket_plan([(64, 32), (16, 48), (3, 64, 32), None, (64, 32)])
    # shapes are canonical (long, short): the wide (16, 48) leaf buckets as
    # (48, 16) with a transpose flag
    assert [b.shape for b in plan] == [(64, 32), (48, 16)]
    big, wide = plan
    assert big.leaf_indices == (0, 2, 4)
    assert big.counts == (1, 3, 1)       # expert stack contributes 3 matrices
    assert big.transposed == (False, False, False)
    assert big.size == 5
    assert big.key == "64x32"
    assert wide.leaf_indices == (1,) and wide.size == 1
    assert wide.transposed == (True,)


def test_bucket_plan_merges_transpose_partners():
    """(m, n) and (n, m) leaves share one canonical bucket (w_up/w_down)."""
    (b,) = build_bucket_plan([(16, 64), (64, 16), (2, 16, 64)])
    assert b.shape == (64, 16)
    assert b.leaf_indices == (0, 1, 2)
    assert b.transposed == (True, False, True)
    assert b.size == 4


def test_bucket_plan_flattens_deep_leading_dims():
    (b,) = build_bucket_plan([(2, 3, 8, 4)])
    assert b.shape == (8, 4) and b.counts == (6,)


def test_bucket_plan_rejects_vectors():
    with pytest.raises(ValueError):
        build_bucket_plan([(7,)])


# ---------------------------------------------------------------------------
# parity with the per-leaf reference engine
# ---------------------------------------------------------------------------

def _run(cfg, params, grads, steps):
    tx = sumo(0.01, cfg)
    state = tx.init(params)
    updates = None
    for _ in range(steps):
        updates, state = tx.update(grads, state, params)
    return updates, state


@pytest.mark.parametrize("steps", [1, 2], ids=["refresh-step", "plain-step"])
def test_bucketed_bitmatches_per_leaf(steps):
    """Same deltas and same Q/M/prev_norm after a refresh step (step 0) and a
    non-refresh step (step 1): the engines are the same optimizer."""
    key = jax.random.PRNGKey(0)
    params = _tree(key)
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=2, weight_decay=0.01, bucketed=True,
                     state_layout="leaf")
    u_b, s_b = _run(cfg, params, grads, steps)
    u_l, s_l = _run(dataclasses.replace(cfg, bucketed=False), params, grads, steps)
    for k in params:
        np.testing.assert_array_equal(np.asarray(u_b[k]), np.asarray(u_l[k]))
        np.testing.assert_array_equal(np.asarray(s_b.Q[k]), np.asarray(s_l.Q[k]))
        np.testing.assert_array_equal(np.asarray(s_b.M[k]), np.asarray(s_l.M[k]))
        np.testing.assert_array_equal(
            np.asarray(s_b.prev_norm[k]), np.asarray(s_l.prev_norm[k])
        )


def test_bucketed_weight_decay_with_partial_params():
    """A bucket mixing leaves with and without a param must still decay the
    leaves that have one (the per-leaf engine's semantics), not silently
    drop decay for the whole bucket."""
    key = jax.random.PRNGKey(3)
    params = {"a": jax.random.normal(key, (32, 16)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (32, 16))}
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    partial = {"a": params["a"], "b": None}
    cfg = SumoConfig(rank=4, update_freq=2, weight_decay=0.1, bucketed=True)

    tx_b = sumo(0.01, cfg)
    tx_l = sumo(0.01, dataclasses.replace(cfg, bucketed=False))
    u_b, _ = tx_b.update(grads, tx_b.init(params), partial)
    u_l, _ = tx_l.update(grads, tx_l.init(params), partial)
    for k in params:
        np.testing.assert_array_equal(np.asarray(u_b[k]), np.asarray(u_l[k]))
    # and the decayed leaf really differs from the undecayed one's path
    u_nw, _ = tx_b.update(grads, tx_b.init(params), None)
    assert float(jnp.max(jnp.abs(u_b["a"] - u_nw["a"]))) > 0
    np.testing.assert_array_equal(np.asarray(u_b["b"]), np.asarray(u_nw["b"]))


def test_bucketed_adaptive_refresh_realigns_basis():
    """Bucket-granular refresh_quality: a subspace switch re-aligns Q before
    the K-step cadence (the bucketed analogue of the per-leaf criterion)."""
    key = jax.random.PRNGKey(4)
    m, n, r = 64, 32, 4
    U1 = jnp.linalg.qr(jax.random.normal(key, (m, r)))[0]
    full = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 9), (m, m)))[0]
    U2 = full[:, m - r:]
    C = jax.random.normal(jax.random.fold_in(key, 1), (r, n))
    params = {"w": jnp.zeros((m, n))}

    def run(quality):
        tx = sumo(0.01, SumoConfig(rank=r, update_freq=1000, bucketed=True,
                                   state_layout="leaf",
                                   refresh_quality=quality))
        state = tx.init(params)
        _, state = tx.update({"w": U1 @ C}, state, params)
        _, state = tx.update({"w": U2 @ C}, state, params)
        return float(jnp.linalg.norm(U2.T @ state.Q["w"])) / np.sqrt(r)

    assert run(0.5) > 0.9
    assert run(0.0) < 0.3


# ---------------------------------------------------------------------------
# Pallas projection inside the optimizer path
# ---------------------------------------------------------------------------

def test_pallas_projection_matches_reference_in_optimizer():
    """project_pallas/backproject_pallas (interpret mode on CPU) vs the plain
    QᵀG / QO matmuls, inside the bucketed update: ≤ 1e-5."""
    key = jax.random.PRNGKey(1)
    params = {"w": jax.random.normal(key, (96, 40)),
              "e": jax.random.normal(jax.random.fold_in(key, 1), (2, 96, 40))}
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)
    cfg = SumoConfig(rank=8, update_freq=2, projection="pallas",
                     state_layout="leaf")
    u_p, s_p = _run(cfg, params, grads, 2)
    u_r, s_r = _run(dataclasses.replace(cfg, projection="reference"),
                    params, grads, 2)
    for k in params:
        np.testing.assert_allclose(np.asarray(u_p[k]), np.asarray(u_r[k]),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_p.M[k]), np.asarray(s_r.M[k]),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# lowering: one refresh cond per bucket
# ---------------------------------------------------------------------------

def _count_conditionals(tx, grads, state):
    txt = jax.jit(lambda g, s: tx.update(g, s)).lower(grads, state)\
        .compile().as_text()
    return len(re.findall(r"\bconditional\(", txt))


@pytest.mark.slow
def test_one_refresh_cond_per_bucket():
    """24 same-shaped matrices + one odd one = 2 buckets ⇒ exactly 2
    conditionals in the optimized HLO; the per-leaf engine compiles 25."""
    key = jax.random.PRNGKey(2)
    params = {f"layer{i:02d}": {"w": jax.random.normal(jax.random.fold_in(key, i),
                                                       (64, 32))}
              for i in range(24)}
    params["odd"] = jax.random.normal(jax.random.fold_in(key, 99), (16, 8))
    grads = jax.tree_util.tree_map(lambda x: x * 0.01, params)

    tx_b = sumo(0.01, SumoConfig(rank=8, update_freq=10, bucketed=True))
    assert _count_conditionals(tx_b, grads, tx_b.init(params)) == 2

    tx_l = sumo(0.01, SumoConfig(rank=8, update_freq=10, bucketed=False))
    assert _count_conditionals(tx_l, grads, tx_l.init(params)) == 25
