"""SUMO extended features: adaptive refresh criterion (Alg. 1 alternative /
Theorem 3.8 T_ℓ times), schedule, chain/clip composition."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Schedule,
    SumoConfig,
    apply_updates,
    chain,
    clip_by_global_norm,
    sumo,
)


def test_adaptive_refresh_triggers_on_subspace_rotation():
    """With refresh_quality set, a sudden gradient-subspace change refreshes Q
    before the K-step cadence would."""
    key = jax.random.PRNGKey(0)
    m, n, r = 64, 32, 4
    U1 = jnp.linalg.qr(jax.random.normal(key, (m, r)))[0]
    # orthogonal complement directions for the post-switch gradient
    full = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 9), (m, m)))[0]
    U2 = full[:, m - r:]
    C = jax.random.normal(jax.random.fold_in(key, 1), (r, n))
    params = {"w": jnp.zeros((m, n))}

    def run(quality):
        tx = sumo(0.01, SumoConfig(rank=r, update_freq=1000, state_layout="leaf",
                                   refresh_quality=quality))
        state = tx.init(params)
        _, state = tx.update({"w": U1 @ C}, state, params)     # step 0: refresh
        Q_before = state.Q["w"]
        _, state = tx.update({"w": U2 @ C}, state, params)     # subspace switch
        Q_after = state.Q["w"]
        # overlap of Q_after with the NEW subspace U2
        return float(jnp.linalg.norm(U2.T @ Q_after)) / np.sqrt(r), Q_before

    cap_adaptive, _ = run(quality=0.5)
    cap_fixed, _ = run(quality=0.0)
    assert cap_adaptive > 0.9          # adaptive refresh re-aligned the basis
    assert cap_fixed < 0.3             # fixed-K kept the stale basis


def test_schedule_warmup_cosine():
    s = Schedule(peak_lr=1.0, warmup_steps=10, total_steps=100, final_frac=0.1)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(5))) == 0.5
    assert float(s(jnp.asarray(100))) <= 0.1 + 1e-6
    # monotone decreasing after warmup
    vals = [float(s(jnp.asarray(t))) for t in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_chain_with_clipping():
    params = {"w": jnp.zeros((16, 8))}
    tx = chain(clip_by_global_norm(1.0), sumo(0.1, SumoConfig(rank=4)))
    state = tx.init(params)
    g = {"w": jnp.full((16, 8), 100.0)}
    u, state = tx.update(g, state, params)
    assert np.isfinite(float(jnp.linalg.norm(u["w"])))


def test_sumo_update_is_spectral_direction():
    """The SUMO step direction (before limiter/scale) has ~unit singular
    values in the projected subspace — the steepest-descent-under-spectral-
    norm property the paper builds on."""
    key = jax.random.PRNGKey(2)
    params = {"w": jnp.zeros((64, 32))}
    cfg = SumoConfig(rank=8, update_freq=1000, rms_scale=False, alpha=1.0,
                     gamma=1e9)
    tx = sumo(1.0, cfg)
    state = tx.init(params)
    g = jax.random.normal(key, (64, 32))
    u, state = tx.update({"w": g}, state, params)
    s = jnp.linalg.svd(u["w"], compute_uv=False)
    # top-8 singular values equal (spectral-ball extreme point), rest ~0
    np.testing.assert_allclose(np.asarray(s[:8]) / float(s[0]), 1.0, atol=1e-3)
    assert float(s[8]) < 1e-3 * float(s[0])
