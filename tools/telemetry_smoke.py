"""Tier-1 telemetry smoke: a short probes+sink+controller train run must
emit a non-empty, schema-valid JSONL stream. Run by tools/run_tier1.sh as

    PYTHONPATH=src python tools/telemetry_smoke.py

Exit code 0 iff every assertion holds.
"""
from __future__ import annotations

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.telemetry import read_jsonl, validate_record
from repro.train import TrainConfig, train


def main() -> int:
    arch = get_smoke_config("smollm-360m")
    shape = ShapeConfig("tel-smoke", seq_len=32, global_batch=4, kind="train")
    out = os.path.join(tempfile.mkdtemp(prefix="sumo-telemetry-"),
                       "telemetry.jsonl")
    steps, freq = 8, 3
    res = train(
        arch, shape,
        TrainConfig(optimizer="sumo", learning_rate=3e-3, rank=8,
                    update_freq=freq, total_steps=steps, log_every=10**9,
                    telemetry=True, telemetry_out=out, controller=True),
        log_fn=lambda s: None,
    )

    recs = read_jsonl(out)
    assert recs, f"telemetry smoke: no records written to {out}"
    for rec in recs:
        validate_record(rec)
    buckets = {r["bucket"] for r in recs}
    steps_seen = {r["step"] for r in recs}
    assert len(recs) == len(buckets) * steps, (
        f"expected {len(buckets)} buckets x {steps} steps, got {len(recs)}")
    assert steps_seen == set(range(steps)), sorted(steps_seen)
    fired = {r["step"] for r in recs if r["refresh_fired"]}
    assert 0 in fired, "step-0 refresh must fire"
    assert res.telemetry_records == len(recs)
    print(f"telemetry smoke OK: {len(recs)} schema-valid records, "
          f"{len(buckets)} buckets, refreshes at steps {sorted(fired)}, "
          f"{len(res.controller_events)} controller events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
