"""Hillclimb diagnostic: recompile one dry-run cell and print the top
collective ops (with jax source attribution) + top memory-traffic regions.

    PYTHONPATH=src python tools/diagnose_cell.py qwen3-4b train_4k
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPE_BY_NAME, get_config  # noqa: E402
from repro.core import SumoConfig, sumo_optimizer  # noqa: E402
from repro.launch.dryrun import _abstract_params, _named  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import input_specs  # noqa: E402
from repro.parallel import input_specs_sharding, opt_state_specs, tree_param_specs  # noqa: E402
from repro.roofline.hlo_cost import (  # noqa: E402
    analyze_hlo,
    top_bytes,
    top_collectives,
    top_dots,
)
from repro.train.steps import make_train_step  # noqa: E402


def main(arch_id: str, shape_name: str, hints: str = "off") -> None:
    cfg = get_config(arch_id)
    shape = SHAPE_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    if hints == "on":
        from repro.models.layers import set_sharding_hints
        set_sharding_hints(("data",), "model", dict(mesh.shape))
    params_s = _abstract_params(cfg)
    param_sh = _named(tree_param_specs(params_s, mesh, cfg), mesh)
    batch_s = input_specs(cfg, shape)
    batch_sh = _named(input_specs_sharding(batch_s, mesh, shape.global_batch), mesh)
    with mesh:
        tx = sumo_optimizer(1e-3, params_s, SumoConfig(rank=128, update_freq=200))
        opt_s = jax.eval_shape(tx.init, params_s)
        opt_sh = _named(opt_state_specs(opt_s, mesh, cfg), mesh)
        step = make_train_step(cfg, tx, attn_impl="flash")
        metric_sh = {k: NamedSharding(mesh, P())
                     for k in ("loss", "grad_norm", "update_norm")}
        compiled = jax.jit(
            step, in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, metric_sh),
        ).lower(params_s, opt_s, batch_s).compile()
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)
    print(f"flops/dev={cost.flops:.3e} bytes/dev={cost.bytes:.3e} "
          f"coll/dev={cost.collective_bytes:.3e}")
    print("\ntop collectives:")
    for e in top_collectives(hlo, k=12):
        src = e["source"][:110]
        print(f"  {e['bytes']/1e9:8.1f}GB  ×{e['mult']:<5.0f} {e['op']:18s} "
              f"{e['shape'][:40]:40s} {src}")
    print("\ntop dots:")
    for e in top_dots(hlo, k=12):
        src = e["source"][:110]
        print(f"  {e['flops']/1e12:8.2f}TF  ×{e['mult']:<5.0f} "
              f"{e['shape'][:40]:40s} {src}")
    print("\ntop bytes:")
    for e in top_bytes(hlo, k=14):
        src = e["source"][:100]
        print(f"  {e['bytes']/1e9:8.1f}GB  ×{e['mult']:<7.0f} {e['opcode']:12s} "
              f"{e['shape'][:36]:36s} {src}")


if __name__ == "__main__":
    main(*sys.argv[1:])
