#!/usr/bin/env python
"""Diff two static-analysis JSON reports; fail CI on check-set regressions.

    python tools/analysis_diff.py GOLDEN NEW [--require-mode 1d|2d|all]

GOLDEN is the committed reference report (tools/golden/*.json — status per
check name is all the diff reads, so goldens are stored reduced); NEW is a
fresh ``python -m repro.analysis --json`` run. Exit non-zero when:

  * newly-failed: a check FAILs in NEW that was not failing in GOLDEN;
  * silently-disappeared: a check named in GOLDEN is absent from NEW
    (a renamed or dropped check must update the golden explicitly);
  * missing-required (with --require-mode): NEW lacks a check name the
    driver's ``--list`` contract requires for that lane — the required
    set comes from ``repro.analysis.driver.list_checks``, never from a
    hardcoded list in shell.

PASS -> SKIP transitions and brand-new checks are reported as warnings
only: device-poor environments skip, and a new pass should not fail the
lane that introduces it. Schema versions may differ between the two
reports (that is the point of versioning) but each must match
``static-analysis-v<N>``.
"""
import argparse
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SCHEMA_RE = re.compile(r"^static-analysis-v\d+$")


def _load(path: str) -> dict:
    with open(path) as f:
        rep = json.load(f)
    schema = rep.get("schema", "")
    if not SCHEMA_RE.match(schema):
        raise SystemExit(f"{path}: schema {schema!r} does not match "
                         f"{SCHEMA_RE.pattern}")
    return rep


def _statuses(rep: dict) -> dict:
    return {c["name"]: c["status"] for c in rep.get("checks", [])}


def diff(golden: dict, new: dict, require_mode: str = "") -> tuple:
    """Returns (failures, warnings) as lists of strings."""
    gold, cur = _statuses(golden), _statuses(new)
    failures, warnings = [], []
    for name, status in sorted(gold.items()):
        if name not in cur:
            failures.append(f"silently-disappeared: '{name}' ({status} in "
                            f"golden) is absent from the new report")
        elif cur[name] == "FAIL" and status != "FAIL":
            failures.append(f"newly-failed: '{name}' was {status}, now FAIL")
        elif cur[name] == "SKIP" and status == "PASS":
            warnings.append(f"'{name}' was PASS, now SKIP (fewer devices?)")
    for name in sorted(set(cur) - set(gold)):
        warnings.append(f"new check '{name}' ({cur[name]}) not in golden — "
                        f"update the golden to start tracking it")
    if require_mode:
        from repro.analysis.driver import list_checks
        required = {c["name"] for c in list_checks(require_mode)}
        for name in sorted(required - set(cur)):
            failures.append(f"missing-required: mode '{require_mode}' "
                            f"requires check '{name}' (driver --list) but "
                            f"the new report does not contain it")
    return failures, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff two static-analysis JSON reports.")
    ap.add_argument("golden")
    ap.add_argument("new")
    ap.add_argument("--require-mode", choices=("1d", "2d", "all"),
                    default="", help="also require every check name the "
                    "driver lists for this lane to be present")
    args = ap.parse_args(argv)
    failures, warnings = diff(_load(args.golden), _load(args.new),
                              args.require_mode)
    for w in warnings:
        print(f"warning: {w}")
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        print(f"analysis-diff: FAIL ({len(failures)} regressions)")
        return 1
    print(f"analysis-diff: OK ({len(warnings)} warnings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
