#!/usr/bin/env bash
# Tier-1 verify: the ROADMAP's canonical test command, with PYTHONPATH=src
# wired in so it is one line from anywhere in the repo.
#   tools/run_tier1.sh            # full tier-1 run
#   tools/run_tier1.sh -m 'not slow'   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
