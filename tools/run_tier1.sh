#!/usr/bin/env bash
# Tier-1 verify: the ROADMAP's canonical test command, with PYTHONPATH=src
# wired in so it is one line from anywhere in the repo.
#   tools/run_tier1.sh            # full tier-1 run
#   tools/run_tier1.sh -m 'not slow'   # extra pytest args pass through
#
# Pass 1 runs the whole suite on the default single-device backend (the
# multi-device tests in tests/test_sumo_sharded.py and
# tests/test_rsvd_sharded.py skip there, and their slow subprocess wrappers
# cover them when slow tests are selected). Pass 2 re-runs the sharded tests
# in-process on a forced 8-host-device CPU backend — the 1D (data=8) shard_map
# bucket path, the 2D (data=2, model=4) mesh with model-sharded matrices and
# the distributed rSVD (ragged edge-padded long dims included, plus the
# end-to-end --model-parallel train wiring), the cross-mesh-shape
# checkpoint round trip ((8,1) <-> (2,4)), the compressed DP gradient
# exchange (pmean parity, EF on the real collective, the steady-dp wire
# budget on compiled HLO, end-to-end --dp-compress), and the static-analysis
# sharded suite (inertness proofs + the concatenate-seam budget regression).
# Pass 3
# is the telemetry smoke: a short probes+sink+controller train run must emit
# a non-empty, schema-valid JSONL stream (tools/telemetry_smoke.py). Pass 4
# is the static lint (ANALYSIS.md): both lanes of tools/lint_static.py —
# collective budgets, pad-inertness proofs (incl. the serving null-block
# proof), donation/aliasing + host-dtype audits, the recompile-boundary
# audit, the peak-HBM memory budgets (train step, Table-1 state claim,
# paged serve_decode) and the precision/numerical-stability pass
# (accumulation dtypes, true-wire dtype, eps-guard lint, ortho error
# bound) — with the verdict read from the machine-readable
# static-analysis-v2 JSON report and diffed against the committed goldens
# in tools/golden/ by tools/analysis_diff.py; plus a
# guard that benchmarks/step_time.py reports its collective numbers through
# the shared budget API (one code path with the lint, so CSV and CI cannot
# drift apart). Pass 5 is the
# serving smoke (SERVING.md): benchmarks/serving.py --smoke must produce a
# schema-valid serving-bench-v1 JSON and record exactly one serve_decode
# compile per arch (the no-recompile slot contract on the real engine).
set -euo pipefail
cd "$(dirname "$0")/.."

# Guard: compiled bytecode must never be tracked (PR 3 untracked the last).
if git ls-files -- '*.pyc' '*.pyo' | grep -q .; then
  echo "ERROR: tracked Python bytecode files:" >&2
  git ls-files -- '*.pyc' '*.pyo' >&2
  exit 1
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
  python -m pytest -x -q tests/test_sumo_sharded.py tests/test_rsvd_sharded.py \
  tests/test_analysis_sharded.py tests/test_compression_sharded.py \
  "tests/test_checkpoint.py::test_cross_mesh_checkpoint_round_trip_8dev" \
  -k "not subprocess"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python tools/telemetry_smoke.py

# Pass 4: machine-checked static guarantees (ANALYSIS.md). The 1d lane also
# runs the donation/host-dtype and recompile audits, the memory-budget pass
# (train step, Table 1, paged serve_decode) and the precision pass
# (accumulation dtypes, DP true-wire dtype, refresh eps-guard lint, the
# paper's ortho error bound); the 2d lane re-proves inertness, the guard and
# ortho-bound lints, and the collective budgets on the (data, model) mesh.
# Each lane emits the static-analysis-v2 JSON report; each lane must FAIL
# nothing (the lint's own exit code) AND diff clean against its committed
# golden (tools/analysis_diff.py) — newly-failed or silently-disappeared
# checks fail CI by name, and the required-check set comes from the
# driver's --list contract, never from a list hardcoded here.
LINT_JSON_1D="$(mktemp /tmp/lint_static_1d.XXXXXX.json)"
LINT_JSON_2D="$(mktemp /tmp/lint_static_2d.XXXXXX.json)"
python tools/lint_static.py --mode 1d --devices 2 --json > "$LINT_JSON_1D"
python tools/lint_static.py --mode 2d --devices 8 --json > "$LINT_JSON_2D"
python tools/analysis_diff.py tools/golden/static_analysis_1d.json \
  "$LINT_JSON_1D" --require-mode 1d
python tools/analysis_diff.py tools/golden/static_analysis_2d.json \
  "$LINT_JSON_2D" --require-mode 2d
rm -f "$LINT_JSON_1D" "$LINT_JSON_2D"
# Guard: the benchmark must report collective numbers through the shared
# budget API, not a private audit that can drift from the lint.
if ! grep -q "repro.analysis.collectives" benchmarks/step_time.py; then
  echo "ERROR: benchmarks/step_time.py no longer uses the shared" \
       "repro.analysis.collectives budget API (see ANALYSIS.md)" >&2
  exit 1
fi

# Pass 5: serving smoke — schema-valid open-loop bench JSON + zero
# off-boundary serve_decode recompiles (exit code carries the verdict).
SERVING_BENCH_OUT="$(mktemp /tmp/bench_serving.XXXXXX.json)"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
  python benchmarks/serving.py --smoke --out "$SERVING_BENCH_OUT"
rm -f "$SERVING_BENCH_OUT"
