#!/usr/bin/env python
"""Tier-1 entry point for the repro.analysis static passes.

Sets the host-platform device count BEFORE importing jax, so the same
script drives both lint lanes:

    python tools/lint_static.py --mode 1d --devices 2
    python tools/lint_static.py --mode 2d --devices 8

``--json`` passes through to the driver: the machine-readable
static-analysis-v2 report on stdout (what tools/run_tier1.sh consumes)
instead of the human PASS/FAIL log. ``--list`` passes through too: just
the required check names/lanes for the mode (no jax work) — what
tools/analysis_diff.py reads as the required set.

An explicit XLA_FLAGS in the environment wins over --devices.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("1d", "2d", "all"), default="all")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = leave XLA alone)")
    ap.add_argument("--json", action="store_true",
                    help="emit the static-analysis-v2 JSON report on stdout")
    ap.add_argument("--list", action="store_true", dest="list_checks",
                    help="print required check names/lanes and exit")
    args = ap.parse_args()
    if args.devices and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}")
    from repro.analysis.driver import main as driver_main
    argv = ["--mode", args.mode] + (["--json"] if args.json else []) \
        + (["--list"] if args.list_checks else [])
    return driver_main(argv)


if __name__ == "__main__":
    sys.exit(main())
