"""Render ROOFLINE_TABLE.md from a dry-run sweep JSON.

    python tools/render_roofline.py dryrun_optimized.json ROOFLINE_TABLE.md
"""
import json
import sys

NOTES = {
    ("memory", "train"): "Pallas flash/SSD kernel path keeps score panels in VMEM (jnp fallback streams them); bf16 halves the CPU-promoted f32 traffic",
    ("memory", "prefill"): "same as train: kernel-resident panels + bf16",
    ("memory", "decode"): "weight+KV streaming floor — raise batch or quantize KV to move it",
    ("memory", "long_decode"): "state streaming floor — inherent at batch 1",
    ("collective", "train"): "remaining ARs are Megatron row-parallel outputs; bf16 halves them; 2D sharding trades AR for AG",
    ("collective", "prefill"): "TP activation collectives; sequence-parallel already applied",
    ("collective", "decode"): "KV-cache head/seq resharding; fewer model-parallel ways at decode would trade vs HBM",
    ("collective", "long_decode"): "ring-cache resharding at batch 1",
    ("compute", "train"): "compute-bound — at roofline; only kernel-level MXU utilization remains",
    ("compute", "prefill"): "compute-bound — at roofline",
}


def main(src: str, dst: str) -> None:
    rows = json.load(open(src))
    ok = [r for r in rows if r.get("status") == "ok" and r["mesh"] == "16x16"]
    sk = [r for r in rows if r.get("status") == "skipped" and r["mesh"] == "16x16"]
    ok.sort(key=lambda r: (r["arch"], r["shape"]))

    out = ["# Roofline table — single-pod (16×16 = 256 chips), post-§Perf sweep",
           "",
           "All terms are per-device seconds/step from the compiled dry-run",
           "(trip-count-aware HLO walker; see EXPERIMENTS.md §Roofline for the",
           "two CPU-lowering biases). `useful` = MODEL_FLOPS / global HLO FLOPs.",
           "",
           "| arch | shape | t_compute | t_memory | t_collective | bottleneck | MODEL_FLOPS | useful | roofline frac | next lever |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in ok:
        kind = {"train_4k": "train", "prefill_32k": "prefill",
                "decode_32k": "decode", "long_500k": "long_decode"}[r["shape"]]
        note = NOTES.get((r["bottleneck"], kind), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} | {note} |"
        )
    out.append("")
    out.append("Skipped cells (architectural, per assignment):")
    for r in sk:
        out.append(f"* {r['arch']} × {r['shape']} — {r['reason']}")
    out.append("")
    out.append("Multi-pod (2×16×16) rows live in the same JSON; every supported "
               "cell compiles there too (the `pod` axis carries only "
               "data-parallel gradient traffic).")
    with open(dst, "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"wrote {dst}: {len(ok)} ok rows, {len(sk)} skips")


if __name__ == "__main__":
    main(*sys.argv[1:])
